"""Async engine driver: deadline policy (fake clock), lifecycle
(start/stop/drain/abort), exception propagation, backpressure, and
multi-threaded stress against a mutating corpus.

Every blocking wait in this file carries an explicit timeout so a deadlocked
driver fails the test instead of hanging the suite (CI additionally runs
with pytest-timeout and PYTHONFAULTHANDLER=1).
"""

import threading
import time

import numpy as np
import pytest

from repro.engine import (
    BucketPolicy,
    DeadlineBatcher,
    DeadlineExceeded,
    DriverQueueFull,
    DriverStopped,
    EngineDriver,
    FaultToleranceConfig,
    RetrievalEngine,
    SearchRequest,
)

RNG = np.random.default_rng(23)
D = 16
WAIT = 30.0          # generous future timeout: only hit on driver bugs


def make_engine(n_docs=64, **kw):
    kw.setdefault("d_start", 4)
    kw.setdefault("k0", 8)
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("capacity", 256)
    kw.setdefault("block_n", 32)
    eng = RetrievalEngine(D, **kw)
    db = RNG.normal(size=(n_docs, D)).astype(np.float32)
    eng.add_docs(db)
    return eng, db


class TestDeadlineBatcher:
    """Pure policy decisions under a fake clock — no threads, no sleeping."""

    POLICY = BucketPolicy((1, 2, 4, 8))

    def test_idle_when_empty(self):
        b = DeadlineBatcher(self.POLICY, max_wait_s=0.5)
        assert b.decide(0, 0.0, 100.0).action == "idle"

    def test_waits_before_deadline_with_exact_remaining(self):
        b = DeadlineBatcher(self.POLICY, max_wait_s=0.5)
        d = b.decide(3, oldest_arrival=10.0, now=10.2)
        assert d.action == "wait"
        assert d.wait_s == pytest.approx(0.3)

    def test_flushes_partial_batch_at_deadline(self):
        b = DeadlineBatcher(self.POLICY, max_wait_s=0.5)
        d = b.decide(3, oldest_arrival=10.0, now=10.5)
        assert (d.action, d.n, d.reason) == ("flush", 3, "deadline")
        # ... and well past it
        d = b.decide(3, oldest_arrival=10.0, now=99.0)
        assert (d.action, d.n, d.reason) == ("flush", 3, "deadline")

    def test_full_bucket_flushes_ignoring_deadline(self):
        b = DeadlineBatcher(self.POLICY, max_wait_s=1e9)
        d = b.decide(8, oldest_arrival=10.0, now=10.0)
        assert (d.action, d.n, d.reason) == ("flush", 8, "full")
        # oversized backlog still flushes exactly one top bucket
        d = b.decide(23, oldest_arrival=10.0, now=10.0)
        assert (d.action, d.n, d.reason) == ("flush", 8, "full")

    def test_zero_wait_flushes_on_arrival(self):
        b = DeadlineBatcher(self.POLICY, max_wait_s=0.0)
        d = b.decide(1, oldest_arrival=10.0, now=10.0)
        assert (d.action, d.n, d.reason) == ("flush", 1, "deadline")

    def test_negative_wait_rejected(self):
        with pytest.raises(ValueError, match="max_wait_s"):
            DeadlineBatcher(self.POLICY, max_wait_s=-0.001)

    def test_wait_shrinks_as_clock_advances(self):
        b = DeadlineBatcher(self.POLICY, max_wait_s=1.0)
        w1 = b.decide(2, 0.0, 0.25).wait_s
        w2 = b.decide(2, 0.0, 0.75).wait_s
        assert w1 == pytest.approx(0.75) and w2 == pytest.approx(0.25)
        assert w2 < w1


class TestLifecycle:
    def test_context_manager_serves_and_rejects_after_exit(self):
        eng, db = make_engine()
        with EngineDriver(eng, max_wait_ms=1.0) as driver:
            assert driver.running
            res = driver.retrieve(db[3], timeout=WAIT)
            assert res.doc_ids[0] == 3
        assert not driver.running
        with pytest.raises(DriverStopped):
            driver.submit(db[0])

    def test_double_start_raises(self):
        eng, _ = make_engine()
        driver = EngineDriver(eng).start()
        try:
            with pytest.raises(RuntimeError, match="already"):
                driver.start()
        finally:
            driver.stop()

    def test_stop_is_idempotent(self):
        eng, _ = make_engine()
        driver = EngineDriver(eng).start()
        driver.stop()
        driver.stop()                            # no error, no hang

    def test_stop_drain_completes_every_accepted_request(self):
        eng, db = make_engine()
        # huge deadline: nothing would flush on its own before stop()
        driver = EngineDriver(eng, max_wait_ms=60_000).start()
        futures = [driver.submit(db[i]) for i in range(11)]
        driver.stop(drain=True, timeout=WAIT)
        ids = [f.result(WAIT).doc_ids[0] for f in futures]
        assert ids == list(range(11))
        assert driver.stats.n_completed == 11
        assert driver.stats.n_cancelled == 0

    def test_stop_abort_cancels_pending_futures(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000).start()
        futures = [driver.submit(db[i]) for i in range(3)]
        driver.stop(drain=False, timeout=WAIT)
        for f in futures:
            with pytest.raises(DriverStopped):
                f.result(WAIT)
        assert driver.stats.n_cancelled == 3

    def test_unstarted_driver_drains_inline_on_stop(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000)
        fut = driver.submit(db[5])               # accepted before start()
        driver.stop(drain=True)
        assert fut.result(0).doc_ids[0] == 5

    def test_concurrent_abort_cannot_revoke_drain_promise(self):
        """A stop(drain=False) racing an in-progress stop(drain=True) must
        not flip the drain policy: every accepted request is still served."""
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000).start()
        futures = [driver.submit(db[i]) for i in range(9)]
        first = threading.Thread(
            target=driver.stop, kwargs={"drain": True}, daemon=True)
        first.start()
        # wait until the draining stop owns the shutdown...
        t0 = time.perf_counter()
        while driver.running and time.perf_counter() - t0 < WAIT:
            time.sleep(0.001)
        driver.stop(drain=False)                 # ...then try to abort it
        first.join(timeout=WAIT)
        assert not first.is_alive()
        ids = [f.result(WAIT).doc_ids[0] for f in futures]
        assert ids == list(range(9))
        assert driver.stats.n_cancelled == 0

    def test_stop_retry_after_timeout_can_abort(self):
        """Regression: a drain stop() whose join timed out left the driver
        wedged in the stopping state forever — a later stop(drain=False)
        could not downgrade the drain policy and reclaim the thread."""
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:hang@every=1,s=0.4"))
        driver = EngineDriver(eng, max_wait_ms=0.0).start()
        futs = [driver.submit(db[i]) for i in range(4)]
        # every dispatch wedges 0.4s, so a short drain timeout must fire
        with pytest.raises(TimeoutError):
            driver.stop(drain=True, timeout=0.05)
        # the retry downgrades drain -> abort and reclaims the thread
        driver.stop(drain=False, timeout=WAIT)
        assert not driver.running
        for f in futs:                # served by the wedged dispatch, or
            try:                      # cancelled by the abort — never stuck
                f.result(WAIT)
            except DriverStopped:
                pass
        with pytest.raises(DriverStopped):
            driver.submit(db[0])

    def test_submit_during_drain_is_rejected(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000)
        driver.submit(db[0])
        driver.stop(drain=True)
        with pytest.raises(DriverStopped):
            driver.submit(db[1])


class TestExpiredShedding:
    """Regression: a flushed group whose members ALL expired must not
    dispatch an empty batch, must count each shed exactly once in
    ``n_expired``, and must still count the flush under its reason."""

    def test_all_expired_group_never_dispatches(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000)   # unstarted
        futs = [driver.submit(SearchRequest(db[i], deadline_ms=0.01))
                for i in range(3)]
        time.sleep(0.05)                      # every client budget expires
        batches_before = eng.stats.n_batches
        driver.stop(drain=True)               # inline drain forms the batch
        for f in futs:
            with pytest.raises(DeadlineExceeded):
                f.result(0)
        assert driver.stats.n_expired == 3    # each shed counted exactly once
        assert driver.stats.n_completed == 0
        assert driver.stats.n_flush_drain == 1   # the flush still happened
        assert eng.stats.n_batches == batches_before   # no empty dispatch

    def test_mixed_expiry_dispatches_survivors_once(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000)
        dead = [driver.submit(SearchRequest(db[i], deadline_ms=0.01))
                for i in range(2)]
        live = [driver.submit(SearchRequest(db[i], deadline_ms=600_000.0))
                for i in range(2, 4)]
        time.sleep(0.05)
        batches_before = eng.stats.n_batches
        driver.stop(drain=True)
        for f in dead:
            with pytest.raises(DeadlineExceeded):
                f.result(0)
        assert [f.result(0).doc_ids[0] for f in live] == [2, 3]
        assert driver.stats.n_expired == 2
        assert driver.stats.n_completed == 2
        assert driver.stats.n_flush_drain == 1
        assert eng.stats.n_batches == batches_before + 1


class TestServing:
    def test_retrieve_matches_engine_search(self):
        eng, db = make_engine()
        q = db[:7] + 0.01 * RNG.normal(size=(7, D)).astype(np.float32)
        _, direct = eng.search(q)
        with EngineDriver(eng, max_wait_ms=0.0) as driver:
            got = np.stack(
                [driver.retrieve(v, timeout=WAIT).doc_ids for v in q])
        np.testing.assert_array_equal(got, direct)

    def test_full_bucket_flushes_without_waiting_deadline(self):
        eng, db = make_engine()
        eng.warmup()
        # deadline is a minute: only the full-bucket rule can flush in time
        with EngineDriver(eng, max_wait_ms=60_000) as driver:
            futures = [driver.submit(v) for v in db[:4]]
            ids = [f.result(WAIT).doc_ids[0] for f in futures]
        assert ids == [0, 1, 2, 3]
        assert driver.stats.n_flush_full == 1
        assert driver.stats.n_flush_deadline == 0

    def test_deadline_flushes_partial_batch(self):
        eng, db = make_engine()
        eng.warmup()
        with EngineDriver(eng, max_wait_ms=20.0) as driver:
            res = driver.retrieve(db[2], timeout=WAIT)   # lone request
        assert res.doc_ids[0] == 2
        assert res.stats.batch_fill == 1
        assert driver.stats.n_flush_deadline == 1

    def test_request_latency_includes_driver_queue_wait(self):
        eng, db = make_engine()
        eng.warmup()
        with EngineDriver(eng, max_wait_ms=50.0) as driver:
            res = driver.retrieve(db[0], timeout=WAIT)
        # the ~50ms deadline wait happened in the driver's queue, but it must
        # be charged to the request's engine-side latency split
        assert res.stats.queue_ms >= 25.0
        assert res.stats.latency_ms >= res.stats.queue_ms

    def test_backpressure_blocks_then_raises_queue_full(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000, max_queue=2)
        driver.submit(db[0])
        driver.submit(db[1])                     # queue now full (not started)
        t0 = time.perf_counter()
        with pytest.raises(DriverQueueFull):
            driver.submit(db[2], timeout=0.05)
        assert time.perf_counter() - t0 >= 0.04  # it actually waited
        driver.stop(drain=False)

    def test_result_timeout_raises(self):
        eng, db = make_engine()
        driver = EngineDriver(eng, max_wait_ms=60_000)  # never started
        fut = driver.submit(db[0])
        with pytest.raises(TimeoutError):
            fut.result(0.05)
        driver.stop(drain=False)

    def test_dispatch_exception_propagates_and_driver_survives(self):
        eng, db = make_engine()
        eng.warmup()
        boom = {"armed": True}
        orig = eng.backend.search

        def exploding_search(*a, **kw):
            if boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("injected backend failure")
            return orig(*a, **kw)

        eng.backend.search = exploding_search
        try:
            with EngineDriver(eng, max_wait_ms=0.0) as driver:
                bad = driver.submit(db[0])
                with pytest.raises(RuntimeError, match="injected"):
                    bad.result(WAIT)
                assert bad.exception(0) is not None
                # the driver thread survived the batch failure
                ok = driver.retrieve(db[1], timeout=WAIT)
                assert ok.doc_ids[0] == 1
            assert driver.stats.n_batch_errors == 1
        finally:
            eng.backend.search = orig

    def test_future_exception_is_none_on_success(self):
        eng, db = make_engine()
        with EngineDriver(eng, max_wait_ms=0.0) as driver:
            fut = driver.submit(db[0])
            assert fut.exception(WAIT) is None
            assert fut.done()

    def test_bad_query_rejected_at_submit_not_in_driver_thread(self):
        eng, _ = make_engine()
        with EngineDriver(eng) as driver:
            with pytest.raises(ValueError, match="query vector"):
                driver.submit(np.zeros((3, D), np.float32))
        assert driver.stats.n_submitted == 0


class TestConcurrency:
    @pytest.mark.slow
    def test_stress_many_clients_racing_mutations(self):
        """≥ 8 client threads retrieving while mutators add/delete docs.

        Every future must resolve with ids that were valid at dispatch time
        (in-range or the -1 sentinel), and the engine's counters must
        reconcile exactly afterwards — the whole point of engine.lock.
        """
        n_clients, per_client = 8, 12
        # compaction off: it remaps the ids the mutators hold between their
        # add and delete calls (correct behavior, but it's the interleave
        # test in test_backends.py that exercises the remap protocol — this
        # test pins the locking/stats story with stable ids)
        eng, db = make_engine(n_docs=96, capacity=1024,
                              compact_dead_frac=None)
        eng.warmup()
        errors = []
        stop_mutating = threading.Event()

        def mutator(seed):
            rng = np.random.default_rng(seed)
            try:
                while not stop_mutating.is_set():
                    ids = eng.add_docs(
                        rng.normal(size=(3, D)).astype(np.float32))
                    eng.delete_docs(ids[:1])
                    time.sleep(0.001)
            except Exception as e:
                errors.append(e)

        def client(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(per_client):
                    q = db[rng.integers(len(db))]
                    res = driver.retrieve(q, timeout=WAIT)
                    ids = res.doc_ids
                    ok = (ids == -1) | ((ids >= 0) & (ids < 1 << 30))
                    assert ok.all(), f"malformed ids {ids}"
            except Exception as e:
                errors.append(e)

        with EngineDriver(eng, max_wait_ms=1.0, max_queue=64) as driver:
            mutators = [threading.Thread(target=mutator, args=(100 + i,),
                                         daemon=True) for i in range(2)]
            clients = [threading.Thread(target=client, args=(i,),
                                        daemon=True) for i in range(n_clients)]
            for t in mutators + clients:
                t.start()
            for t in clients:
                t.join(timeout=WAIT)
                assert not t.is_alive(), "client thread hung"
            stop_mutating.set()
            for t in mutators:
                t.join(timeout=WAIT)
                assert not t.is_alive(), "mutator thread hung"
        assert not errors, errors[:3]
        assert driver.stats.n_completed == n_clients * per_client
        s = eng.stats.summary()
        assert s["n_submitted"] == s["n_completed"] == n_clients * per_client
        assert s["n_docs_added"] == eng.store.total_added
        assert s["n_docs_deleted"] == eng.store.total_deleted

    @pytest.mark.slow
    def test_stats_counters_reconcile_under_races(self):
        """Race-detection for the engine-lock fix: unguarded ``+=`` on the
        stats counters from many threads drifts; with engine.lock the totals
        must reconcile exactly."""
        # compaction off: ids held across another thread's safe point would
        # be remapped (see test_backends.py for that protocol); counters are
        # what's under test here
        eng, db = make_engine(n_docs=32, capacity=2048,
                              compact_dead_frac=None)
        eng.warmup()
        n_threads, iters = 6, 25
        errors = []

        def hammer(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(iters):
                    ids = eng.add_docs(
                        rng.normal(size=(2, D)).astype(np.float32))
                    eng.delete_docs(ids[1:])
                    rid = eng.submit(db[rng.integers(len(db))])
                    eng.step()
                    eng.poll(rid)                # may be None if another
                    # thread's step served it; either way it was completed
            except Exception as e:
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(i,), daemon=True)
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=WAIT)
            assert not t.is_alive(), "hammer thread hung"
        assert not errors, errors[:3]
        eng.run_until_idle()
        s = eng.stats.summary()
        total = n_threads * iters
        assert s["n_submitted"] == s["n_completed"] == total
        assert s["n_docs_added"] == eng.store.total_added == 32 + 2 * total
        assert s["n_docs_deleted"] == eng.store.total_deleted == total

    @pytest.mark.slow
    def test_driver_with_background_rebuilds_and_appends(self):
        """Background index rebuilds adopt at driver safe points while
        clients keep retrieving; appended docs stay reachable throughout."""
        eng = RetrievalEngine(
            D, d_start=4, k0=8, buckets=(1, 2, 4), capacity=512, block_n=32,
            backend="quantized", backend_opts={"min_rebuild_rows": 16},
            rebuild_mode="background",
        )
        rng = np.random.default_rng(3)
        base = rng.normal(size=(64, D)).astype(np.float32)
        eng.add_docs(base)
        eng.warmup()
        errors = []

        def client(seed):
            r = np.random.default_rng(seed)
            try:
                for _ in range(10):
                    i = r.integers(len(base))
                    res = driver.retrieve(base[i], timeout=WAIT)
                    assert (res.doc_ids >= -1).all()
            except Exception as e:
                errors.append(e)

        with EngineDriver(eng, max_wait_ms=0.5) as driver:
            threads = [threading.Thread(target=client, args=(i,), daemon=True)
                       for i in range(4)]
            for t in threads:
                t.start()
            # force churn past the rebuild threshold while clients run
            for _ in range(6):
                eng.add_docs(rng.normal(size=(8, D)).astype(np.float32))
                time.sleep(0.005)
            for t in threads:
                t.join(timeout=WAIT)
                assert not t.is_alive()
        assert not errors, errors[:3]
        # Churn (48 appended rows) is past the rebuild threshold; drive the
        # safe point until the background build is launched AND adopted —
        # deterministic, instead of hoping the clients' dispatches raced the
        # mutator at the right moments.
        deadline = time.perf_counter() + WAIT
        while eng.stats.n_rebuilds < 2:
            eng.maybe_rebuild()
            assert time.perf_counter() < deadline, "rebuild never adopted"
            time.sleep(0.01)
        # a fresh doc appended after all that is immediately retrievable
        probe = rng.normal(size=(1, D)).astype(np.float32) * 5.0
        [nid] = eng.add_docs(probe)
        _, idx = eng.search(probe)
        assert idx[0, 0] == nid

"""Training loop, optimizer, and checkpointing behaviour."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.checkpoint.ckpt import all_steps
from repro.configs.base import LMConfig
from repro.data import lm_batch_stream, recsys_batch_stream
from repro.models import lm as LM
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.train import TrainLoop, make_train_step

TINY = LMConfig(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                d_head=16, d_ff=64, vocab=64, param_dtype="float32",
                compute_dtype="float32", remat=False)


class TestOptim:
    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((2, 2)) * 10.0}
        clipped, gn = clip_by_global_norm(g, 1.0)
        total = np.sqrt(sum(float(jnp.sum(x**2))
                            for x in jax.tree.leaves(clipped)))
        np.testing.assert_allclose(total, 1.0, rtol=1e-5)
        np.testing.assert_allclose(float(gn), np.sqrt(8 * 100), rtol=1e-5)

    def test_cosine_schedule_shape(self):
        lrs = [float(cosine_schedule(jnp.asarray(s), base_lr=1.0,
                                     warmup=10, total=100)) for s in range(100)]
        assert lrs[0] < lrs[9]                   # warmup rises
        assert max(lrs) <= 1.0 + 1e-6
        assert lrs[99] < lrs[20]                 # decays
        assert lrs[99] >= 0.1 - 1e-6             # min_ratio floor

    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        opt = adamw_init(params)
        for _ in range(200):
            g = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(params, g, opt, lr=5e-2,
                                          weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_compression_dtype(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        opt = adamw_init(params)
        g = {"w": jnp.full((4,), 0.123456789, jnp.float32)}
        p1, _, _ = adamw_update(params, g, opt, lr=1e-2,
                                grad_dtype="bfloat16")
        p2, _, _ = adamw_update(params, g, opt, lr=1e-2)
        # compressed path differs slightly but stays finite/close
        assert bool(jnp.isfinite(p1["w"]).all())
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   rtol=1e-2)


class TestTrainLoop:
    def test_lm_loss_decreases(self):
        rng = np.random.default_rng(0)
        loop = TrainLoop(
            lambda p, b: LM.lm_loss(p, b, TINY),
            lambda: LM.init_lm(jax.random.PRNGKey(0), TINY),
            lm_batch_stream(rng, TINY.vocab, 8, 16),
            log_every=5, base_lr=2e-3, warmup=5, total_steps=60)
        loop.run(60)
        first = loop.history[0]["loss"]
        last = np.mean([h["loss"] for h in loop.history[-3:]])
        assert last < first - 0.1, (first, last)

    def test_grad_accum_matches_full_batch(self):
        """accum_steps microbatching == one big batch (same grads)."""
        params = LM.init_lm(jax.random.PRNGKey(0), TINY)
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = jax.tree.map(jnp.asarray,
                             next(lm_batch_stream(rng, TINY.vocab, 8, 16)))
        s1 = make_train_step(lambda p, b: LM.lm_loss(p, b, TINY),
                             accum_steps=1, donate=False)
        s4 = make_train_step(lambda p, b: LM.lm_loss(p, b, TINY),
                             accum_steps=4, donate=False)
        p1, _, m1 = s1(params, opt, batch)
        p4, _, m4 = s4(params, opt, batch)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)

    def test_restart_resumes_step(self, tmp_path):
        rng = np.random.default_rng(0)
        mk = lambda: TrainLoop(
            lambda p, b: LM.lm_loss(p, b, TINY),
            lambda: LM.init_lm(jax.random.PRNGKey(0), TINY),
            lm_batch_stream(rng, TINY.vocab, 4, 8),
            ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
        loop = mk()
        loop.run(10)
        loop2 = mk()
        assert loop2.start_step == 10
        # opt step restored
        assert int(loop2.state[1].step) == 10


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": [jnp.ones((4,), jnp.bfloat16),
                      jnp.zeros((2,), jnp.int32)]}
        save_checkpoint(str(tmp_path), 7, tree)
        restored, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_retention(self, tmp_path):
        tree = {"x": jnp.zeros((2,))}
        for s in range(6):
            save_checkpoint(str(tmp_path), s, tree, keep=3)
        assert all_steps(str(tmp_path)) == [3, 4, 5]

    def test_partial_write_ignored(self, tmp_path):
        tree = {"x": jnp.zeros((2,))}
        save_checkpoint(str(tmp_path), 1, tree)
        # simulate a crash mid-write: tmp dir without manifest
        os.makedirs(tmp_path / "step_00000002.tmp")
        # and a renamed dir missing its manifest
        os.makedirs(tmp_path / "step_00000003")
        assert latest_step(str(tmp_path)) == 1

    def test_async_manager(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"x": jnp.arange(4.0)}
        mgr.save_async(3, tree)
        mgr.wait()
        restored, step = mgr.restore(tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.arange(4.0))


class TestRecsysTraining:
    @pytest.mark.parametrize("family", ["dlrm", "din"])
    def test_ctr_loss_decreases(self, family):
        from repro.configs import get_arch
        from repro.models import recsys as RS
        arch = {"dlrm": "dlrm-rm2", "din": "din"}[family]
        cfg = get_arch(arch).SMOKE_CONFIG
        rng = np.random.default_rng(0)
        loop = TrainLoop(
            lambda p, b: RS.recsys_loss(p, b, cfg),
            lambda: RS.recsys_init(jax.random.PRNGKey(0), cfg),
            recsys_batch_stream(rng, cfg.family, 128,
                                n_sparse=cfg.n_sparse or 6,
                                vocab=cfg.vocab_per_field,
                                n_dense=cfg.n_dense or 13,
                                seq_len=cfg.seq_len or 10),
            log_every=10, base_lr=5e-3, warmup=10, total_steps=150)
        loop.run(150)
        first = loop.history[0]["loss"]
        last = np.mean([h["loss"] for h in loop.history[-3:]])
        assert last < first - 0.003, (first, last)

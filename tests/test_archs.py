"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness.  The FULL
configs are exercised only by the dry-run (launch/dryrun.py)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import LM_ARCHS, RECSYS_ARCHS, get_arch, list_archs
from repro.data import lm_batch_stream, recsys_batch_stream
from repro.models import lm as LM
from repro.models import egnn as EG
from repro.models import recsys as RS
from repro.models.graph import batched_molecules, random_graph
from repro.optim import adamw_init, adamw_update

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)


def _one_train_step(loss_fn, params, batch):
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    opt = adamw_init(params)
    new_params, _, om = adamw_update(params, grads, opt, lr=1e-3)
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    return loss, delta


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    mod = get_arch(arch)
    cfg = mod.SMOKE_CONFIG
    params = LM.init_lm(KEY, cfg)
    batch = next(lm_batch_stream(np.random.default_rng(0), cfg.vocab, 2, 16))
    batch = {"tokens": jnp.asarray(batch["tokens"])}

    logits, aux = LM.lm_forward(params, batch["tokens"][:, :-1], cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    loss, delta = _one_train_step(
        lambda p, b: LM.lm_loss(p, b, cfg), params, batch)
    assert bool(jnp.isfinite(loss)) and delta > 0

    # decode smoke
    cache = LM.init_cache(cfg, 2, 8)
    lg, cache2 = LM.decode_step(params, cache, batch["tokens"][:, :1], 0, cfg)
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())

    # prefill smoke
    plog, pcache = LM.prefill(params, batch["tokens"][:, :8], cfg)
    assert plog.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(plog).all())


def test_egnn_smoke_full_graph():
    mod = get_arch("egnn")
    cfg = mod.SMOKE_CONFIG
    g = random_graph(RNG, 64, 256, cfg.d_feat_in, n_classes=cfg.n_classes)
    params = EG.egnn_init(KEY, cfg)
    logits, coords = EG.egnn_forward(params, g, cfg)
    assert logits.shape == (64, cfg.n_classes)
    assert coords.shape == (64, 3)
    loss, delta = _one_train_step(
        lambda p, b: EG.egnn_loss(p, b, cfg), params, g)
    assert bool(jnp.isfinite(loss)) and delta > 0


def test_egnn_smoke_molecules():
    mod = get_arch("egnn")
    cfg = mod.SMOKE_CONFIG
    g = batched_molecules(RNG, 4, 10, 20, cfg.d_feat_in,
                          n_classes=cfg.n_classes)
    params = EG.egnn_init(KEY, cfg)
    loss, m = EG.egnn_loss(params, g, cfg)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    mod = get_arch(arch)
    cfg = mod.SMOKE_CONFIG
    params = RS.recsys_init(KEY, cfg)
    batch = next(recsys_batch_stream(
        np.random.default_rng(0), cfg.family, 8,
        n_sparse=cfg.n_sparse or 6, vocab=cfg.vocab_per_field,
        n_dense=cfg.n_dense or 13, seq_len=cfg.seq_len or 10))
    batch = jax.tree.map(jnp.asarray, batch)
    loss, delta = _one_train_step(
        lambda p, b: RS.recsys_loss(p, b, cfg), params, batch)
    assert bool(jnp.isfinite(loss)) and delta > 0

    # candidate-scoring smoke (the retrieval_cand serving path, reduced)
    cand = jnp.arange(32, dtype=jnp.int32)
    s = RS.serve_candidates(params, batch, cand, cfg)
    assert s.shape == (8, 32)
    assert bool(jnp.isfinite(s).all())


def test_two_tower_progressive_retrieval_integration():
    """The paper's technique as the two-tower serving path."""
    mod = get_arch("two-tower-retrieval")
    cfg = mod.SMOKE_CONFIG
    params = RS.recsys_init(KEY, cfg)
    nf = max(cfg.n_sparse // 2, 1)
    item_ids = jnp.asarray(
        RNG.integers(0, cfg.vocab_per_field, (500, nf, 1)), jnp.int32)
    db = RS.tower_item(params, item_ids)
    user_ids = jnp.asarray(
        RNG.integers(0, cfg.vocab_per_field, (4, nf, 1)), jnp.int32)
    scores, idx = RS.retrieval_serve(params, user_ids, db, cfg, k=5)
    assert idx.shape == (4, 5)
    assert bool((idx >= 0).all()) and bool((idx < 500).all())
    # progressive result must equal brute-force top-1 on the same DB when
    # k0 covers the gap
    from repro.core import truncated_search
    q = RS.tower_user(params, user_ids)
    _, brute = truncated_search(q.astype(jnp.float32),
                                db.astype(jnp.float32),
                                dim=db.shape[1], k=1)
    from repro.core import make_schedule
    sched = make_schedule(cfg.retrieval_d_start, db.shape[1], 500)
    _, prog = RS.retrieval_serve(params, user_ids, db, cfg, sched=sched, k=1)
    assert (np.asarray(prog[:, 0]) == np.asarray(brute[:, 0])).all()


def test_all_archs_resolvable():
    assert len(list_archs()) == 10
    for a in list_archs():
        mod = get_arch(a)
        assert hasattr(mod, "CONFIG") and hasattr(mod, "SMOKE_CONFIG")
        assert hasattr(mod, "SHAPES") and len(mod.SHAPES) == 4


def test_param_counts_match_published_scale():
    """Full configs land in the published parameter range."""
    expect = {
        "starcoder2-3b": (2.5e9, 4e9),
        "gemma3-4b": (3e9, 5.5e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "qwen3-moe-235b-a22b": (210e9, 260e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_arch(arch).CONFIG
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]"
    # MoE active params
    ds = get_arch("deepseek-v2-236b").CONFIG
    assert 15e9 <= ds.active_param_count() <= 35e9
    qw = get_arch("qwen3-moe-235b-a22b").CONFIG
    assert 15e9 <= qw.active_param_count() <= 30e9

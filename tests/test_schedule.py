"""Schedule construction invariants (paper §III.D parameterization)."""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import make_schedule, validate_schedule


def test_paper_example_schedule():
    # (Ds=128, Dm=2048, K=16) from Table III row 2
    s = make_schedule(128, 2048, 16)
    assert [(st_.dim, st_.k) for st_ in s.stages] == [
        (128, 16), (256, 8), (512, 4), (1024, 2), (2048, 1)]
    assert s.stages[0].pool == -1
    assert [st_.pool for st_ in s.stages[1:]] == [16, 8, 4, 2]


def test_single_stage_when_equal_dims():
    s = make_schedule(256, 256, 32)
    assert len(s.stages) == 1
    assert s.stages[0].dim == 256


def test_final_stage_exact_dmax_non_power_of_two():
    s = make_schedule(128, 3584, 64)   # paper Table III row 3
    assert s.stages[-1].dim == 3584
    assert s.stages[-1].k == 1
    dims = [x.dim for x in s.stages]
    assert dims == sorted(set(dims))


def test_validation_errors():
    with pytest.raises(ValueError):
        make_schedule(0, 128, 4)
    with pytest.raises(ValueError):
        make_schedule(256, 128, 4)
    with pytest.raises(ValueError):
        make_schedule(16, 128, 0)
    s = make_schedule(64, 128, 4)
    with pytest.raises(ValueError):
        validate_schedule(s, n_db=2, d_emb=128)    # k0 > N
    with pytest.raises(ValueError):
        validate_schedule(s, n_db=100, d_emb=64)   # d_max > D


@given(
    d_start=st.sampled_from([16, 32, 64, 128, 256, 512]),
    mult=st.integers(1, 6),
    k0=st.sampled_from([1, 2, 4, 8, 16, 64, 256, 1024]),
)
@settings(max_examples=60, deadline=None)
def test_schedule_properties(d_start, mult, k0):
    d_max = d_start * (2 ** mult)
    s = make_schedule(d_start, d_max, k0)
    dims = [x.dim for x in s.stages]
    ks = [x.k for x in s.stages]
    # dims strictly increasing, start/end pinned
    assert dims[0] == d_start and dims[-1] == d_max
    assert all(a < b for a, b in zip(dims, dims[1:]))
    # intermediate dims double
    for a, b in zip(dims[:-1], dims[1:-1]):
        assert b == 2 * a
    # K non-increasing, >= 1, ends at final_k
    assert all(a >= b for a, b in zip(ks, ks[1:]))
    assert all(k >= 1 for k in ks)
    assert ks[-1] == 1
    validate_schedule(s, n_db=10**9, d_emb=d_max)

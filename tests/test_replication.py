"""WAL-shipped replication: cursor tailing (rotation, pruning, gaps, torn
tails), follower bootstrap + catch-up, ``recover()`` corner cases for both
roles, the router's failure-handling primitives, and the replicated HTTP
surface (read-only followers, ``min_seq`` tokens, readiness, failover)."""

import os
import time

import numpy as np
import pytest

from repro.engine import (
    EngineDriver,
    FaultPlan,
    InjectedFault,
    MutationWAL,
    PrimaryReplication,
    ReplicaApplier,
    ReplicationConfig,
    RetrievalEngine,
    WALCursor,
    WALError,
    WALGap,
)
from repro.serve import CircuitBreaker, ReplicaRouter, RetryPolicy

D = 16
RNG = np.random.default_rng(11)


def fresh_engine(capacity=256):
    return RetrievalEngine(D, d_start=8, k0=8, final_k=4, buckets=(1, 2),
                           capacity=capacity, block_n=64)


def make_primary(state_dir, n_docs=6):
    eng = fresh_engine()
    eng.enable_durability(state_dir)
    if n_docs:
        eng.add_docs(RNG.normal(size=(n_docs, D)).astype(np.float32))
    return eng


def wait_until(pred, timeout=30.0, msg="condition"):
    deadline = time.perf_counter() + timeout
    while not pred():
        if time.perf_counter() >= deadline:
            raise TimeoutError(f"timed out waiting: {msg}")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# WALCursor: the tailing reader the replication channel is built on
# ---------------------------------------------------------------------------
class TestWALCursor:
    def test_poll_returns_records_in_seq_order_once(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        for i in range(5):
            wal.append("add", {"i": i})
        cur = WALCursor(str(tmp_path))
        recs = cur.poll()
        assert [r.seq for r in recs] == [0, 1, 2, 3, 4]
        assert cur.applied_seq == 4
        assert cur.poll() == []                 # nothing new: no re-read
        wal.append("add", {"i": 5})
        assert [r.seq for r in cur.poll()] == [5]
        wal.close()

    def test_poll_spans_rotation(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        wal.append("add", {})
        wal.rotate()
        wal.append("add", {})
        wal.rotate()
        wal.append("add", {})
        cur = WALCursor(str(tmp_path))
        assert [r.seq for r in cur.poll()] == [0, 1, 2]
        wal.close()

    def test_max_records_resumes_where_it_stopped(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        for _ in range(6):
            wal.append("add", {})
        cur = WALCursor(str(tmp_path))
        assert [r.seq for r in cur.poll(max_records=2)] == [0, 1]
        assert [r.seq for r in cur.poll(max_records=3)] == [2, 3, 4]
        assert [r.seq for r in cur.poll()] == [5]
        wal.close()

    def test_seek_rewinds_and_skips(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        for _ in range(4):
            wal.append("add", {})
        cur = WALCursor(str(tmp_path))
        cur.poll()
        cur.seek(1)
        assert [r.seq for r in cur.poll()] == [2, 3]
        cur.seek(10)                            # ahead of the tail: nothing
        assert cur.poll() == []
        wal.close()

    def test_prune_behind_cursor_is_invisible(self, tmp_path):
        # regression: pruning consumed segments must not disturb the
        # cursor or resurface old records (the prune-under-tail bug)
        wal = MutationWAL(str(tmp_path), fsync=False)
        for _ in range(3):
            wal.append("add", {})
        cur = WALCursor(str(tmp_path))
        assert len(cur.poll()) == 3
        wal.rotate()
        wal.append("add", {})
        assert wal.prune(upto_seq=2) == 1       # the consumed segment
        assert [r.seq for r in cur.poll()] == [3]
        assert cur.poll() == []

        # and pruning between two polls of the SAME segment set
        wal.rotate()
        wal.append("add", {})
        wal.prune(upto_seq=3)
        assert [r.seq for r in cur.poll()] == [4]
        wal.close()

    def test_prune_ahead_of_cursor_raises_gap(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        for _ in range(3):
            wal.append("add", {})
        wal.rotate()
        wal.append("add", {})
        wal.prune(upto_seq=2)                   # drops seqs 0-2
        cur = WALCursor(str(tmp_path))          # wants everything from 0
        with pytest.raises(WALGap):
            cur.poll()
        wal.close()

    def test_torn_newest_tail_returns_clean_prefix(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        for _ in range(3):
            wal.append("add", {"pad": "x" * 64})
        wal.close()
        segs = sorted(os.listdir(tmp_path))
        path = os.path.join(tmp_path, segs[-1])
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) - 7)   # tear the last record
        cur = WALCursor(str(tmp_path))
        recs = cur.poll()                       # no raise: writer mid-append
        assert [r.seq for r in recs] == [0, 1]
        assert cur.poll() == []

    def test_last_available_seq_and_lag(self, tmp_path):
        wal = MutationWAL(str(tmp_path), fsync=False)
        cur = WALCursor(str(tmp_path))
        assert cur.last_available_seq() == -1
        assert cur.lag() == 0
        for _ in range(4):
            wal.append("add", {})
        assert cur.last_available_seq() == 3
        assert cur.lag() == 4
        cur.poll()
        assert cur.lag() == 0
        wal.close()

    def test_missing_dir_is_empty_not_error(self, tmp_path):
        cur = WALCursor(str(tmp_path / "nonexistent"))
        assert cur.poll() == []
        assert cur.lag() == 0


# ---------------------------------------------------------------------------
# recover() corner cases, both roles (empty dir / snapshot-only / WAL-only)
# ---------------------------------------------------------------------------
class TestRecoverCorners:
    def test_primary_empty_state_dir(self, tmp_path):
        eng = fresh_engine()
        report = eng.recover(str(tmp_path))
        assert report["snapshot_step"] is None
        assert report["replayed"] == 0
        assert eng.n_docs == 0
        assert eng.wal is not None              # durability is now armed
        eng.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
        assert eng.wal.last_seq == 0
        eng.wal.close()

    def test_follower_empty_state_dir(self, tmp_path):
        eng = fresh_engine()
        applier = ReplicaApplier(eng, str(tmp_path))
        report = applier.bootstrap()
        assert report["snapshot_step"] is None
        assert applier.applied_seq == -1
        assert applier.ready()                  # nothing to lag behind
        assert eng.wal is None                  # follower never opens a WAL
        assert applier.catch_up() == 0

    def test_primary_snapshot_with_zero_wal_tail(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=5)
        prim.save_snapshot()
        prim.wal.close()
        eng = fresh_engine()
        report = eng.recover(str(tmp_path))
        assert report["snapshot_step"] is not None
        assert report["replayed"] == 0
        assert eng.n_docs == 5

    def test_follower_snapshot_with_zero_wal_tail(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=5)
        prim.save_snapshot()
        foll = fresh_engine()
        applier = ReplicaApplier(foll, str(tmp_path))
        report = applier.bootstrap()
        assert report["snapshot_step"] is not None
        assert foll.n_docs == 5
        assert applier.catch_up() == 0          # nothing past the snapshot
        assert applier.applied_seq == prim.wal.last_seq
        prim.wal.close()

    def test_primary_wal_only(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=4)
        prim.delete_docs([0])
        prim.wal.close()
        eng = fresh_engine()
        report = eng.recover(str(tmp_path))
        assert report["snapshot_step"] is None
        assert report["replayed"] == 2          # one add batch + one delete
        assert eng.n_docs == 3                  # live docs: 4 added - 1
        assert not eng.store.is_live(0)
        eng.wal.close()

    def test_follower_wal_only(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=4)
        prim.delete_docs([0])
        foll = fresh_engine()
        applier = ReplicaApplier(foll, str(tmp_path))
        report = applier.bootstrap()
        assert report["snapshot_step"] is None
        assert applier.catch_up() == 2
        assert foll.n_docs == 3                 # live docs: 4 added - 1
        assert not foll.store.is_live(0)
        assert applier.applied_seq == prim.wal.last_seq
        prim.wal.close()


# ---------------------------------------------------------------------------
# ReplicaApplier: catch-up, lag, read-your-writes, gap re-bootstrap, faults
# ---------------------------------------------------------------------------
class TestReplicaApplier:
    def test_catch_up_tracks_primary(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=6)
        foll = fresh_engine()
        applier = ReplicaApplier(foll, str(tmp_path))
        applier.bootstrap()
        applier.catch_up()
        assert foll.n_docs == prim.n_docs
        prim.add_docs(RNG.normal(size=(3, D)).astype(np.float32))
        prim.delete_docs([1])
        assert applier.lag() > 0
        applier.catch_up()
        assert applier.lag() == 0
        assert foll.store.n_active == prim.store.n_active
        assert not foll.store.is_live(1)
        # the follower serves the primary's corpus
        q = np.asarray(prim.store.db[2])[None]
        _, ids = foll.search(q)
        assert ids[0, 0] == 2
        prim.wal.close()

    def test_wait_for_seq(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=2)
        foll = fresh_engine()
        applier = ReplicaApplier(foll, str(tmp_path))
        applier.bootstrap()
        want = prim.wal.last_seq
        assert not applier.wait_for_seq(want, timeout_s=0.05)
        applier.catch_up()
        assert applier.wait_for_seq(want, timeout_s=0.05)
        assert PrimaryReplication(prim).wait_for_seq(want, timeout_s=0.0)
        prim.wal.close()

    def test_gap_triggers_rebootstrap(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=4)
        foll = fresh_engine()
        applier = ReplicaApplier(foll, str(tmp_path))
        applier.bootstrap()                     # cursor at seq -1 (no snap)
        # primary snapshots, rotates, and prunes the records the follower
        # never saw: tailing must detect the gap and re-bootstrap
        prim.save_snapshot()
        prim.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
        prim.wal.prune(prim.wal.last_seq - 1)
        assert applier.catch_up() == 0          # the re-bootstrap tick
        assert applier.n_bootstraps == 2
        applier.catch_up()
        assert applier.applied_seq == prim.wal.last_seq
        assert foll.n_docs == prim.n_docs
        prim.wal.close()

    def test_fault_sites_are_retried_not_skipped(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=3)
        foll = fresh_engine()
        foll.faults = FaultPlan.parse(
            "wal_ship:error@first=1;replica_apply:error@first=1")
        applier = ReplicaApplier(foll, str(tmp_path))
        applier.bootstrap()
        with pytest.raises(InjectedFault):      # wal_ship fires on poll
            applier.catch_up()
        assert applier.catch_up() == 0          # replica_apply fires
        assert applier.n_apply_errors == 1
        applier.catch_up()                      # clean: the record was NOT
        assert applier.applied_seq == prim.wal.last_seq   # skipped
        assert foll.n_docs == prim.n_docs
        prim.wal.close()

    def test_background_thread_converges(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=4)
        foll = fresh_engine()
        applier = ReplicaApplier(foll, str(tmp_path), poll_s=0.01)
        applier.bootstrap()
        applier.start()
        try:
            prim.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
            wait_until(lambda: applier.applied_seq == prim.wal.last_seq,
                       msg="applier tails the live WAL")
            assert applier.ready()
        finally:
            applier.stop()
            prim.wal.close()

    def test_apply_replicated_refuses_wal_owner(self, tmp_path):
        prim = make_primary(str(tmp_path), n_docs=1)
        with pytest.raises(WALError):
            prim.apply_replicated(object())
        prim.wal.close()


# ---------------------------------------------------------------------------
# failure-handling primitives shared by router and CLI client
# ---------------------------------------------------------------------------
class TestRetryPolicy:
    def test_retryable_statuses(self):
        rp = RetryPolicy()
        assert all(rp.retryable(s) for s in (0, 503, 504))
        assert not any(rp.retryable(s)
                       for s in (200, 400, 403, 404, 429, 500))

    def test_run_retries_until_final(self):
        rp = RetryPolicy(max_attempts=4, jitter=0.0)
        calls = []

        def fn(attempt):
            calls.append(attempt)
            return (503, {}) if attempt < 2 else (200, {"ok": True})

        status, payload = rp.run(fn, sleep=lambda s: None)
        assert status == 200 and payload["ok"]
        assert calls == [0, 1, 2]

    def test_run_never_retries_4xx(self):
        rp = RetryPolicy(max_attempts=5)
        calls = []

        def fn(attempt):
            calls.append(attempt)
            return 429, {}

        status, _ = rp.run(fn, sleep=lambda s: None)
        assert status == 429 and calls == [0]

    def test_backoff_grows_and_caps(self):
        rp = RetryPolicy(backoff_s=0.1, backoff_max_s=0.4, jitter=0.0)
        assert rp.backoff(0) == pytest.approx(0.1)
        assert rp.backoff(1) == pytest.approx(0.2)
        assert rp.backoff(5) == pytest.approx(0.4)


class TestCircuitBreaker:
    def test_open_half_open_close_cycle(self):
        now = [0.0]
        br = CircuitBreaker(threshold=2, open_s=1.0, open_max_s=4.0,
                            clock=lambda: now[0])
        assert br.allow()
        br.record_failure()
        assert br.allow()                       # one failure: still closed
        br.record_failure()
        assert br.state == "open" and not br.allow()
        now[0] = 1.01                           # backoff elapsed
        assert br.allow()                       # non-consuming check
        br.on_attempt()                         # the trial is claimed here
        assert br.state == "half_open"
        assert not br.allow()                   # single trial in flight
        br.record_success()
        assert br.state == "closed" and br.allow()

    def test_reopen_doubles_backoff_capped(self):
        now = [0.0]
        br = CircuitBreaker(threshold=1, open_s=1.0, open_max_s=2.0,
                            clock=lambda: now[0])
        br.record_failure()                     # trip 1: 1s
        now[0] = 1.01
        br.allow(), br.on_attempt()
        br.record_failure()                     # trip 2: 2s
        now[0] = 2.0
        assert not br.allow()
        now[0] = 3.02
        br.allow(), br.on_attempt()
        br.record_failure()                     # trip 3: capped at 2s
        assert br.summary()["n_trips"] == 3
        now[0] = 5.05
        assert br.allow()


class TestReplicationConfig:
    def test_defaults_and_round_trip(self):
        from repro.engine import EngineConfig

        cfg = EngineConfig(d_emb=D, d_start=8, replication=ReplicationConfig(
            role="follower", poll_s=0.02, ready_lag_max=3))
        again = EngineConfig.from_dict(cfg.to_dict())
        assert again.replication == cfg.replication
        assert ReplicationConfig().role == "single"

    def test_validation(self):
        with pytest.raises(ValueError):
            ReplicationConfig(role="leader")
        with pytest.raises(ValueError):
            ReplicationConfig(poll_s=0.0)
        with pytest.raises(ValueError):
            ReplicationConfig(ready_lag_max=-1)
        with pytest.raises(ValueError):
            ReplicationConfig.from_dict({"role": "single", "bogus": 1})


# ---------------------------------------------------------------------------
# the replicated HTTP surface: primary + read-only follower + router
# ---------------------------------------------------------------------------
@pytest.fixture()
def replicated(tmp_path):
    from repro.serve import serve_in_thread

    state = str(tmp_path / "state")
    prim = make_primary(state, n_docs=0)
    foll = fresh_engine()
    applier = ReplicaApplier(foll, state, poll_s=0.01)
    applier.bootstrap()
    applier.start()
    with EngineDriver(prim, max_wait_ms=1.0) as pdrv, \
            EngineDriver(foll, max_wait_ms=1.0) as fdrv:
        ph = serve_in_thread(prim, pdrv, require_tenant=False,
                             replication=PrimaryReplication(prim))
        fh = serve_in_thread(foll, fdrv, require_tenant=False,
                             replication=applier, read_only=True)
        try:
            yield ph, fh, prim, foll, applier
        finally:
            fh.stop()
            ph.stop()
            applier.stop()
            prim.wal.close()


class TestReplicatedHTTP:
    def test_min_seq_read_your_writes_and_read_only(self, replicated):
        from repro.serve import http_call

        ph, fh, prim, foll, applier = replicated
        vecs = RNG.normal(size=(4, D)).astype(np.float32)
        status, added = http_call(ph.url, "/v1/docs",
                                  {"vectors": vecs.tolist()})
        assert status == 200 and added["seq"] is not None
        status, got = http_call(fh.url, "/v1/search", {
            "query": vecs[2].tolist(), "k": 1, "min_seq": added["seq"],
            "deadline_ms": 10_000})
        assert status == 200
        assert got["ids"][0] == added["ids"][2]

        # followers refuse mutations outright
        status, payload = http_call(fh.url, "/v1/docs",
                                    {"vectors": vecs[:1].tolist()})
        assert status == 403
        status, payload = http_call(fh.url, "/v1/docs/delete",
                                    {"ids": [0]})
        assert status == 403

    def test_health_reports_replication(self, replicated):
        from repro.serve import http_call

        ph, fh, *_ = replicated
        _, h = http_call(fh.url, "/healthz")
        assert h["role"] == "follower" and h["ready"]
        _, deep = http_call(fh.url, "/healthz?deep=1")
        assert deep["deep"]["replication"]["bootstrapped"]
        _, h = http_call(ph.url, "/healthz")
        assert h["role"] == "primary"

    def test_readiness_503_until_bootstrapped(self, tmp_path):
        from repro.serve import http_call, serve_in_thread

        state = str(tmp_path / "state")
        prim = make_primary(state, n_docs=2)
        prim.wal.close()
        foll = fresh_engine()
        applier = ReplicaApplier(foll, state)   # NOT bootstrapped
        with EngineDriver(foll, max_wait_ms=1.0) as drv:
            handle = serve_in_thread(foll, drv, require_tenant=False,
                                     replication=applier, read_only=True)
            try:
                status, _ = http_call(handle.url, "/healthz")
                assert status == 200            # alive
                status, _ = http_call(handle.url, "/healthz?ready=1")
                assert status == 503            # but not ready
                applier.bootstrap()
                applier.catch_up()
                status, _ = http_call(handle.url, "/healthz?ready=1")
                assert status == 200
            finally:
                handle.stop()

    def test_router_spreads_and_fails_over(self, replicated):
        ph, fh, prim, foll, applier = replicated
        vecs = RNG.normal(size=(4, D)).astype(np.float32)
        router = ReplicaRouter([ph.url, fh.url], probe_interval_s=0.05,
                               failure_threshold=2,
                               breaker_open_s=0.1).start()
        try:
            router.wait_ready(2, timeout=30)
            status, added, _ = router.mutate("/v1/docs",
                                             {"vectors": vecs.tolist()})
            assert status == 200
            served_by = set()
            for i in range(8):
                s, payload, by = router.search({
                    "query": vecs[i % 4].tolist(), "k": 1,
                    "min_seq": added["seq"], "deadline_ms": 10_000})
                assert s == 200
                assert payload["ids"][0] == added["ids"][i % 4]
                served_by.add(by)
            assert len(served_by) == 2          # both replicas took reads

            fh.stop()                           # kill the follower
            for i in range(6):
                s, _, by = router.search({
                    "query": vecs[i % 4].tolist(), "k": 1,
                    "deadline_ms": 10_000})
                assert s == 200                 # zero client-visible errors
                assert by == ph.url
            f_ep = next(ep for ep in router.replicas if ep.url == fh.url)
            wait_until(lambda: not f_ep.alive, msg="probe notices the kill")
        finally:
            router.stop()

    def test_router_hedge_delay_knobs(self):
        router = ReplicaRouter(["http://127.0.0.1:1"], hedge_ms=25.0)
        assert router._hedge_delay_s() == pytest.approx(0.025)
        adaptive = ReplicaRouter(["http://127.0.0.1:1"], hedge_ms=0.0)
        assert adaptive._hedge_delay_s() is None     # needs p95 samples
        for ms in [10.0] * 20:
            adaptive._latencies.append(ms)
        assert adaptive._hedge_delay_s() == pytest.approx(0.010, abs=5e-3)
        off = ReplicaRouter(["http://127.0.0.1:1"], hedge_ms=None)
        assert off._hedge_delay_s() is None

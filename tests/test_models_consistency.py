"""Cross-path consistency + physics invariants."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import MoEConfig
from repro.models import lm as LM
from repro.models import egnn as EG
from repro.models.graph import random_graph

RNG = np.random.default_rng(7)
KEY = jax.random.PRNGKey(7)


def _decode_matches_forward(cfg, S=12, atol=5e-4):
    params = LM.init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab)
    full, _ = LM.lm_forward(params, toks, cfg)
    _, pc = LM.prefill(params, toks[:, : S - 1], cfg)
    dc = LM.prefill_to_decode_cache(cfg, pc, S - 1, S)
    dl, _ = LM.decode_step(params, dc, toks[:, S - 1:], S - 1, cfg)
    err = np.abs(np.asarray(dl) - np.asarray(full[:, -1])).max()
    assert err < atol, err


def test_decode_matches_forward_dense():
    _decode_matches_forward(get_arch("starcoder2-3b").SMOKE_CONFIG)


def test_decode_matches_forward_gqa_swiglu():
    _decode_matches_forward(get_arch("mistral-nemo-12b").SMOKE_CONFIG)


def test_decode_matches_forward_local_global():
    _decode_matches_forward(get_arch("gemma3-4b").SMOKE_CONFIG, S=20)


def test_decode_matches_forward_mla():
    cfg = dataclasses.replace(get_arch("deepseek-v2-236b").SMOKE_CONFIG,
                              moe=None)   # isolate MLA from MoE capacity drops
    _decode_matches_forward(cfg)


def test_multi_step_greedy_decode_matches_forward():
    """Decode 4 tokens autoregressively == teacher-forced forward argmax."""
    cfg = get_arch("starcoder2-3b").SMOKE_CONFIG
    params = LM.init_lm(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    total = 12

    logits, pc = LM.prefill(params, prompt, cfg)
    cache = LM.prefill_to_decode_cache(cfg, pc, 8, total)
    toks = jnp.argmax(logits, -1)[:, None]
    seq = [prompt, toks]
    for i in range(3):
        lg, cache = LM.decode_step(params, cache, toks, 8 + i, cfg)
        toks = jnp.argmax(lg, -1)[:, None]
        seq.append(toks)
    decoded = jnp.concatenate(seq, 1)
    # teacher-forced check
    full, _ = LM.lm_forward(params, decoded[:, :-1], cfg)
    greedy = jnp.argmax(full[:, 7:], -1)
    np.testing.assert_array_equal(np.asarray(decoded[:, 8:]),
                                  np.asarray(greedy))


def test_moe_aux_loss_encourages_balance():
    """Uniform routing should give aux loss ~= coef (its minimum)."""
    from repro.layers.moe import moe_apply, moe_init
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, aux_loss_coef=1.0)
    p = moe_init(KEY, 32, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(KEY, (256, 32))
    _, aux = moe_apply(p, x, cfg, "swiglu")
    # minimum is coef * E * k * (1/E) * ... = coef * k for top-k
    assert float(aux) >= cfg.top_k * 0.99
    assert float(aux) < cfg.top_k * 3.0


def test_moe_capacity_drops_bounded():
    """Output of MoE with generous capacity == dense expert mixture."""
    from repro.layers.moe import moe_apply, moe_init
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=8.0)  # no drops
    p = moe_init(KEY, 16, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(KEY, (32, 16))
    y, _ = moe_apply(p, x, cfg, "swiglu")
    # dense reference: route every token through its top-2 explicitly
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ge = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for t in range(32):
        for j in range(2):
            e = int(ge[t, j])
            h = x[t] @ p["w_in"][e]
            g = jax.nn.silu(x[t] @ p["w_gate"][e]) * h
            ref = ref.at[t].add(gv[t, j] * (g @ p["w_out"][e]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_egnn_equivariance():
    """E(3): rotation+translation of inputs rotates coordinate outputs and
    leaves feature logits invariant."""
    cfg = get_arch("egnn").SMOKE_CONFIG
    g = random_graph(RNG, 60, 200, cfg.d_feat_in, n_classes=cfg.n_classes)
    params = EG.egnn_init(KEY, cfg)
    Q = np.linalg.qr(RNG.normal(size=(3, 3)))[0].astype(np.float32)
    t = RNG.normal(size=(3,)).astype(np.float32)
    g2 = dataclasses.replace(g, coords=g.coords @ jnp.asarray(Q) + t)
    l1, x1 = EG.egnn_forward(params, g, cfg)
    l2, x2 = EG.egnn_forward(params, g2, cfg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(x1 @ jnp.asarray(Q) + t),
                               np.asarray(x2), rtol=2e-3, atol=2e-3)


def test_egnn_padded_edges_are_noops():
    """Adding masked (padded) edges must not change any output."""
    cfg = get_arch("egnn").SMOKE_CONFIG
    g = random_graph(RNG, 40, 100, cfg.d_feat_in, n_classes=cfg.n_classes)
    params = EG.egnn_init(KEY, cfg)
    g_pad = dataclasses.replace(
        g,
        senders=jnp.concatenate([g.senders, jnp.full((20,), -1, jnp.int32)]),
        receivers=jnp.concatenate([g.receivers, jnp.full((20,), -1, jnp.int32)]),
        edge_attr=jnp.zeros((120, 0), jnp.float32),
        edge_mask=jnp.concatenate([g.edge_mask, jnp.zeros((20,), bool)]),
    )
    l1, x1 = EG.egnn_forward(params, g, cfg)
    l2, x2 = EG.egnn_forward(params, g_pad, cfg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), atol=1e-5)

"""HTTP serving front-end: end-to-end tests over a live socket — routing,
status mapping, tenancy enforcement, metadata filters, quotas, deadlines,
and the stats surface."""

import http.client
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.engine import EngineDriver, RetrievalEngine
from repro.serve import QuotaExceeded, TenantQuotas, serve_in_thread

D = 32
RNG = np.random.default_rng(21)


def request(url, path, body=None, method=None):
    """One JSON round trip; returns (status, payload)."""
    data = None if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        url + path, data=data,
        method=method or ("POST" if body is not None else "GET"))
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


@pytest.fixture(scope="module")
def served():
    """One engine + driver + HTTP server shared by the module; tests keep
    to their own tenant namespaces so they don't interfere."""
    eng = RetrievalEngine(D, d_start=8, k0=16, final_k=4, buckets=(1, 2, 4),
                          capacity=64, block_n=64)
    quotas = TenantQuotas(
        max_inflight=64,
        overrides={"throttled": {"max_inflight": 1},
                   "capped": {"max_docs": 3}})
    with EngineDriver(eng, max_wait_ms=1.0) as driver:
        handle = serve_in_thread(eng, driver, quotas=quotas)
        try:
            yield handle.url, eng, quotas
        finally:
            handle.stop()


def seed(url, tenant, n=12, metadata=None):
    vecs = RNG.normal(size=(n, D)).astype(np.float32)
    status, payload = request(url, "/v1/docs", {
        "vectors": vecs.tolist(), "tenant": tenant, "metadata": metadata})
    assert status == 200, payload
    return vecs, payload["ids"]


class TestRouting:
    def test_health(self, served):
        url, _, _ = served
        status, payload = request(url, "/healthz")
        assert status == 200 and payload["status"] == "ok"

    def test_unknown_path_404(self, served):
        url, _, _ = served
        status, _ = request(url, "/v2/nope")
        assert status == 404

    def test_wrong_method_405(self, served):
        url, _, _ = served
        status, _ = request(url, "/v1/search")          # GET on a POST route
        assert status == 405

    def test_malformed_json_400(self, served):
        url, _, _ = served
        req = urllib.request.Request(url + "/v1/search", data=b"{oops",
                                     method="POST")
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(req, timeout=10)
        assert e.value.code == 400

    def test_non_object_body_400(self, served):
        url, _, _ = served
        status, _ = request(url, "/v1/search", body=[1, 2, 3])
        assert status == 400

    def test_keep_alive_two_requests_one_connection(self, served):
        url, _, _ = served
        host, port = url.removeprefix("http://").split(":")
        conn = http.client.HTTPConnection(host, int(port), timeout=30)
        try:
            for _ in range(2):
                conn.request("GET", "/healthz")
                resp = conn.getresponse()
                assert resp.status == 200
                resp.read()
        finally:
            conn.close()


class TestSearch:
    def test_self_retrieval_with_per_request_k(self, served):
        url, _, _ = served
        vecs, ids = seed(url, "srch")
        status, payload = request(url, "/v1/search", {
            "query": vecs[3].tolist(), "tenant": "srch", "k": 2})
        assert status == 200, payload
        assert payload["ids"][0] == ids[3]
        assert len(payload["ids"]) <= 2
        assert len(payload["scores"]) == len(payload["ids"])

    def test_tenant_required_400(self, served):
        url, _, _ = served
        status, payload = request(url, "/v1/search", {
            "query": [0.0] * D})
        assert status == 400 and "tenant" in payload["error"]

    def test_tenant_isolation_over_http(self, served):
        url, _, _ = served
        vecs_a, ids_a = seed(url, "iso-a")
        _, ids_b = seed(url, "iso-b")
        status, payload = request(url, "/v1/search", {
            "query": vecs_a[0].tolist(), "tenant": "iso-b"})
        assert status == 200
        assert not set(payload["ids"]) & set(ids_a)
        assert set(payload["ids"]) <= set(ids_b)

    def test_metadata_filter(self, served):
        url, eng, _ = served
        meta = [{"shard": j % 2} for j in range(12)]
        vecs, ids = seed(url, "filt", metadata=meta)
        status, payload = request(url, "/v1/search", {
            "query": vecs[0].tolist(), "tenant": "filt",
            "filter": {"shard": {"$eq": 1}}})
        assert status == 200 and payload["ids"]
        for i in payload["ids"]:
            assert eng.store.metadata_of(i) == {"shard": 1}

    def test_bad_filter_400(self, served):
        url, _, _ = served
        seed(url, "badf", n=2)
        status, payload = request(url, "/v1/search", {
            "query": [0.0] * D, "tenant": "badf",
            "filter": {"x": {"$regex": "a.*"}}})
        assert status == 400 and "$regex" in payload["error"]

    def test_oversized_k_400(self, served):
        url, _, _ = served
        seed(url, "bigk", n=2)
        status, _ = request(url, "/v1/search", {
            "query": [0.0] * D, "tenant": "bigk", "k": 99})
        assert status == 400

    def test_wrong_dim_400(self, served):
        url, _, _ = served
        status, _ = request(url, "/v1/search", {
            "query": [0.0] * (D + 1), "tenant": "dim"})
        assert status == 400

    def test_expired_deadline_504(self, served):
        url, _, _ = served
        vecs, _ = seed(url, "dead", n=2)
        status, payload = request(url, "/v1/search", {
            "query": vecs[0].tolist(), "tenant": "dead",
            "deadline_ms": 1e-4})
        assert status == 504, payload


class TestDocs:
    def test_add_returns_ids(self, served):
        url, eng, _ = served
        _, ids = seed(url, "add", n=3)
        assert len(ids) == 3
        assert all(eng.store.tenant_of(i) == "add" for i in ids)

    def test_add_without_tenant_400(self, served):
        url, _, _ = served
        status, _ = request(url, "/v1/docs", {"vectors": [[0.0] * D]})
        assert status == 400

    def test_bad_metadata_400(self, served):
        url, _, _ = served
        status, _ = request(url, "/v1/docs", {
            "vectors": [[0.0] * D], "tenant": "badm",
            "metadata": {"blob": [1, 2]}})        # list value: not a scalar
        assert status == 400

    def test_delete_own_docs(self, served):
        url, _, _ = served
        vecs, ids = seed(url, "del", n=4)
        status, payload = request(url, "/v1/docs/delete", {
            "ids": ids[:2], "tenant": "del"})
        assert status == 200 and payload["n_deleted"] == 2
        status, payload = request(url, "/v1/search", {
            "query": vecs[0].tolist(), "tenant": "del"})
        assert status == 200
        assert not set(payload["ids"]) & set(ids[:2])

    def test_cross_tenant_delete_403(self, served):
        url, _, _ = served
        _, ids = seed(url, "owner", n=2)
        status, payload = request(url, "/v1/docs/delete", {
            "ids": [ids[0]], "tenant": "thief"})
        assert status == 403, payload

    def test_out_of_range_delete_400(self, served):
        url, _, _ = served
        status, _ = request(url, "/v1/docs/delete", {
            "ids": [10 ** 9], "tenant": "del"})
        assert status == 400


class TestQuotas:
    def test_doc_cap_429(self, served):
        url, _, _ = served
        seed(url, "capped", n=3)                  # cap is exactly 3
        status, payload = request(url, "/v1/docs", {
            "vectors": [[0.0] * D], "tenant": "capped"})
        assert status == 429 and payload["limit"] == "docs"

    def test_inflight_cap_429_and_release(self, served):
        url, _, quotas = served
        vecs, _ = seed(url, "throttled", n=2)
        # hold the single slot from outside: the next HTTP search must be
        # rejected up front, not queued behind it
        quotas.acquire("throttled")
        try:
            status, payload = request(url, "/v1/search", {
                "query": vecs[0].tolist(), "tenant": "throttled"})
            assert status == 429 and payload["limit"] == "inflight"
        finally:
            quotas.release("throttled")
        status, _ = request(url, "/v1/search", {
            "query": vecs[0].tolist(), "tenant": "throttled"})
        assert status == 200                      # slot freed -> serves again

    def test_quota_object_contract(self):
        q = TenantQuotas(max_inflight=1)
        q.acquire("t")
        with pytest.raises(QuotaExceeded):
            q.acquire("t")
        q.release("t")
        q.acquire("t")                            # released slot reusable
        q.release("t")
        with pytest.raises(RuntimeError):
            q.release("t")                        # unbalanced release
        q.acquire(None)                           # tenantless: never limited
        q.check_docs("t", current=0, adding=10)   # max_docs=None: unlimited
        with pytest.raises(QuotaExceeded):
            TenantQuotas(max_docs=5).check_docs("t", current=4, adding=2)


class TestStats:
    def test_stats_surface(self, served):
        url, _, _ = served
        vecs, _ = seed(url, "stats", n=2)
        request(url, "/v1/search", {"query": vecs[0].tolist(),
                                    "tenant": "stats"})
        status, payload = request(url, "/v1/stats")
        assert status == 200
        assert payload["engine"]["n_completed"] >= 1
        assert payload["driver"]["n_submitted"] >= 1
        assert payload["tenants"]["stats"] == 2
        assert payload["quotas"]["max_inflight"] == 64
        assert payload["config"]["d_emb"] == D
        assert payload["config"]["backend"]["backend"] == "flat"
        assert payload["store"]["n_active"] >= 2


class TestConcurrency:
    def test_mixed_tenant_concurrent_searches(self, served):
        """Many tenants racing over one socket pool: every response is 200
        and scoped to its own namespace (mask-key batching under load)."""
        url, eng, _ = served
        tenants = [f"conc-{i}" for i in range(3)]
        seeded = {t: seed(url, t, n=6) for t in tenants}
        errors = []

        def worker(t):
            vecs, ids = seeded[t]
            try:
                for j in range(6):
                    status, payload = request(url, "/v1/search", {
                        "query": vecs[j % 6].tolist(), "tenant": t})
                    assert status == 200, payload
                    assert set(payload["ids"]) <= set(ids), (t, payload)
            except Exception as e:                # surfaced after join
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in tenants for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive(), "worker hung"
        assert not errors, errors[:3]


class TestLifecycle:
    def test_stop_is_idempotent_and_socket_closes(self):
        eng = RetrievalEngine(D, d_start=8, k0=16, buckets=(1,),
                              capacity=16, block_n=32)
        with EngineDriver(eng, max_wait_ms=0.0) as driver:
            handle = serve_in_thread(eng, driver)
            url = handle.url
            status, _ = request(url, "/healthz")
            assert status == 200
            handle.stop()
            handle.stop()                         # second stop: no-op
            with pytest.raises((ConnectionError, urllib.error.URLError)):
                urllib.request.urlopen(url + "/healthz", timeout=2)


class TestQuotaLifecycle:
    """Regression: no path between ``quotas.acquire`` and future delivery
    may leak an in-flight slot — invalid requests, rejected submits, and
    stopped drivers all release exactly once."""

    def test_invalid_request_hammer_never_leaks_inflight(self, served):
        url, _, quotas = served
        vecs, _ = seed(url, "leak", n=4)
        good = vecs[0].tolist()
        bad_bodies = [
            {"tenant": "leak"},                               # missing query
            {"query": [0.0] * (D + 1), "tenant": "leak"},     # bad dim
            {"query": good, "tenant": "leak", "k": 0},        # bad k
            {"query": good, "tenant": "leak", "k": 999},      # k too large
            {"query": good, "tenant": "leak",
             "filter": {"tag": {"$bogus": 1}}},               # bad filter op
            {"query": "not-a-vector", "tenant": "leak"},      # unparseable
            {"query": [[1.0], [2.0, 3.0]], "tenant": "leak"}, # ragged
        ]
        for _ in range(5):
            for body in bad_bodies:
                status, payload = request(url, "/v1/search", body)
                assert status != 200, (body, payload)
                assert quotas.inflight("leak") == 0, body
        assert quotas.inflight("leak") == 0
        # the namespace still serves fine afterwards, and returns its slot
        status, _ = request(url, "/v1/search",
                            {"query": good, "tenant": "leak"})
        assert status == 200
        assert quotas.inflight("leak") == 0

    def test_stopped_driver_rejects_without_leaking(self):
        eng = RetrievalEngine(D, d_start=8, k0=16, buckets=(1,),
                              capacity=16, block_n=32)
        quotas = TenantQuotas(max_inflight=4)
        driver = EngineDriver(eng, max_wait_ms=0.0).start()
        handle = serve_in_thread(eng, driver, quotas=quotas)
        try:
            vecs, _ = seed(handle.url, "dead", n=2)
            driver.stop(drain=True)               # submit now raises
            for _ in range(4):
                status, _ = request(handle.url, "/v1/search", {
                    "query": vecs[0].tolist(), "tenant": "dead"})
                assert status == 503
            assert quotas.inflight("dead") == 0
        finally:
            handle.stop()
            driver.stop()


def raw_search(url, body):
    """Search via http.client so response headers are observable."""
    host, port = url.removeprefix("http://").split(":")
    conn = http.client.HTTPConnection(host, int(port), timeout=30)
    try:
        conn.request("POST", "/v1/search", json.dumps(body).encode(),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        payload = json.loads(resp.read())
        headers = {k.lower(): v for k, v in resp.getheaders()}
        return resp.status, payload, headers
    finally:
        conn.close()


@pytest.fixture(scope="module")
def served_adaptive():
    """Server with the adaptive policy and query cache enabled."""
    from repro.engine import AdaptiveConfig, CacheConfig
    eng = RetrievalEngine(
        D, d_start=8, k0=16, final_k=4, buckets=(1, 2, 4),
        capacity=64, block_n=64,
        adaptive=AdaptiveConfig(enabled=True, levels=2, min_d_start=4),
        cache=CacheConfig(enabled=True, capacity=32))
    with EngineDriver(eng, max_wait_ms=1.0) as driver:
        handle = serve_in_thread(eng, driver)
        try:
            yield handle.url, eng, driver
        finally:
            handle.stop()


class TestAdaptiveSurface:
    def test_degraded_and_cache_headers(self, served_adaptive):
        url, _, _ = served_adaptive
        vecs, _ = seed(url, "hdr", n=6)
        body = {"query": vecs[2].tolist(), "tenant": "hdr"}
        status, payload, headers = raw_search(url, body)
        assert status == 200, payload
        assert headers["degraded"] == "0"
        assert headers["cache"] == "miss"
        assert payload["cached"] is False and payload["degraded_level"] == 0
        status, payload, headers = raw_search(url, body)
        assert status == 200
        assert headers["cache"] == "hit"
        assert payload["cached"] is True

    def test_stats_expose_adaptive_cache_and_mask_cache(self, served_adaptive):
        url, _, _ = served_adaptive
        status, payload = request(url, "/v1/stats")
        assert status == 200
        assert payload["adaptive"]["enabled"] is True
        assert payload["adaptive"]["level"] == 0
        assert payload["cache"]["enabled"] is True
        assert payload["cache"]["capacity"] == 32
        assert set(payload["mask_cache"]) == {"hits", "misses", "entries",
                                              "epoch"}

    def test_plain_server_reports_sections_disabled(self, served):
        url, _, _ = served
        status, payload = request(url, "/v1/stats")
        assert status == 200
        assert payload["adaptive"] == {"enabled": False}
        assert payload["cache"] == {"enabled": False}
        assert "mask_cache" in payload

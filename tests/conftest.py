# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benchmarks must see the real single CPU device.  Only launch/dryrun.py
# fakes 512 devices, in its own process.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

"""Hypothesis property: a cached query result is never served across a
``store_generation`` / ``mask_epoch`` / rebuild bump — for every backend
variant.

Add/delete sequences drive the engine's own lifecycle (tombstone
compaction past ``compact_dead_frac``, background/sync index rebuilds
past ``min_rebuild_rows``), so the three stamp components all move during
a run; the invariant is that a retrieve served with ``cached=True``
implies the stamp has not moved since the entry was inserted.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.engine import CacheConfig, EngineDriver

from test_adaptive import BACKENDS, D, RNG, make_engine

_OPS = st.lists(
    st.sampled_from(["add", "delete", "hot", "hot", "fresh"]),
    min_size=3, max_size=10)

# One driver per backend shared across examples (construction + warm
# compilation dominate; the invariant is a safety property over any
# starting state, so carried-over corpus contents are fine).
_DRIVERS = {}


def _shared_driver(backend):
    if backend not in _DRIVERS:
        eng, _ = make_engine(
            backend, n_docs=48,
            cache=CacheConfig(enabled=True, capacity=32))
        _DRIVERS[backend] = EngineDriver(eng, max_wait_ms=0.0).start()
    return _DRIVERS[backend]


@pytest.fixture(scope="module", autouse=True)
def _stop_shared_drivers():
    yield
    while _DRIVERS:
        _DRIVERS.popitem()[1].stop()


class TestCacheNeverStale:
    HOT = np.random.default_rng(99).normal(size=D).astype(np.float32)

    @pytest.mark.parametrize("backend", BACKENDS)
    @settings(max_examples=5, deadline=None)
    @given(ops=_OPS)
    def test_mutations_always_invalidate(self, backend, ops):
        """Interleave add/delete (which trigger compaction and rebuilds
        through the engine's own lifecycle) with hot-query retrieves; a
        cached serve must imply zero stamp movement since its insert."""
        drv = _shared_driver(backend)
        eng = drv.engine
        last_ids = None       # ids from the last uncached hot serve
        last_stamp = None     # stamp right after that serve
        for op in ops:
            if op == "add":
                eng.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
            elif op == "delete":
                _, ids = eng.search(self.HOT[None, :], k=1)
                eng.delete_docs([int(ids[0, 0])])
            elif op == "fresh":
                q = RNG.normal(size=D).astype(np.float32)
                drv.retrieve(q, timeout=60)
            else:  # hot
                stamp_before = eng.cache_stamp()
                r = drv.retrieve(self.HOT, timeout=60)
                if r.cached:
                    # served from cache => nothing moved since insert
                    assert last_stamp is not None
                    assert stamp_before == last_stamp, (
                        "cached result served across a stamp bump")
                    np.testing.assert_array_equal(r.doc_ids, last_ids)
                    assert r.store_generation == eng.store.generation
                else:
                    last_ids = r.doc_ids
                    last_stamp = eng.cache_stamp()

"""PQ codec unit tests: encode/decode/ADC identities, the quality knobs,
and the quantized backend's churn-aware (frozen-grid) maintenance."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_schedule
from repro.core.pq import (
    auto_pq_m,
    build_pq_index,
    pq_adc_scores,
    pq_decode,
    pq_encode,
    pq_lut,
    pq_progressive_search,
    train_pq,
)
from repro.core.truncated import l2_scores

RNG = np.random.default_rng(77)


def _db(n=300, d=32):
    return jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))


class TestCodec:
    def test_shapes_and_dtypes(self):
        db = _db()
        cb = train_pq(db, m=4, n_codes=32, n_iter=4)
        assert cb.shape == (4, 32, 8) and cb.dtype == jnp.float32
        codes = pq_encode(db, cb)
        assert codes.shape == (300, 4) and codes.dtype == jnp.uint8
        assert pq_decode(codes, cb).shape == db.shape

    def test_adc_equals_l2_to_reconstruction(self):
        """The ADC identity: summing a row's M LUT entries IS the
        rank-equivalent L2 score of the query vs that row's decode."""
        db = _db()
        q = jnp.asarray(RNG.normal(size=(7, 32)).astype(np.float32))
        cb = train_pq(db, m=8, n_codes=64, n_iter=6)
        codes = pq_encode(db, cb)
        adc = pq_adc_scores(pq_lut(q, cb), codes)
        exact = l2_scores(q, pq_decode(codes, cb))
        np.testing.assert_allclose(np.asarray(adc), np.asarray(exact),
                                   rtol=1e-4, atol=1e-3)

    def test_encode_is_optimal_assignment(self):
        """Reconstruction error is bounded by the codebook quantization
        error: no other code assignment reconstructs a row better."""
        db = _db(n=64)
        cb = train_pq(db, m=4, n_codes=16, n_iter=6)
        codes = np.asarray(pq_encode(db, cb))
        best = np.sum((np.asarray(pq_decode(jnp.asarray(codes), cb))
                       - np.asarray(db)) ** 2, axis=1)
        rng = np.random.default_rng(3)
        for _ in range(5):
            other = rng.integers(0, 16, codes.shape).astype(np.uint8)
            err = np.sum((np.asarray(pq_decode(jnp.asarray(other), cb))
                          - np.asarray(db)) ** 2, axis=1)
            assert (best <= err + 1e-4).all()

    def test_more_subspaces_reconstruct_better(self):
        db = _db(n=512, d=32)
        errs = []
        for m in (1, 4, 8):
            cb = train_pq(db, m=m, n_codes=64, n_iter=8)
            rec = pq_decode(pq_encode(db, cb), cb)
            errs.append(float(jnp.mean(jnp.sum((db - rec) ** 2, axis=1))))
        assert errs[0] > errs[1] > errs[2]

    def test_small_corpus_near_exact(self):
        """More codes than rows: k-means degenerates to ~one centroid per
        row and reconstruction is near-exact."""
        db = _db(n=100)
        cb = train_pq(db, m=4, n_codes=256, n_iter=8)
        rec = pq_decode(pq_encode(db, cb), cb)
        rel = (float(jnp.sum((db - rec) ** 2))
               / float(jnp.sum(db ** 2)))
        assert rel < 0.05

    def test_auto_m(self):
        assert auto_pq_m(64) == 8
        assert auto_pq_m(16) == 2
        assert auto_pq_m(8) == 1        # dsub stays >= 8 when small
        assert auto_pq_m(12) == 1       # indivisible: single subspace

    def test_indivisible_m_raises(self):
        with pytest.raises(ValueError, match="not divisible"):
            train_pq(_db(d=32), m=5)

    def test_too_many_codes_raises(self):
        with pytest.raises(ValueError, match="uint8"):
            train_pq(_db(), m=4, n_codes=512)


class TestPqProgressiveSearch:
    def test_self_retrieval_and_exact_final_scores(self):
        db = _db(n=200, d=32)
        sched = make_schedule(8, 32, 16, final_k=3)
        idx = build_pq_index(db, sched, m=2)
        s, i = pq_progressive_search(db[:6], idx, sched)
        np.testing.assert_array_equal(np.asarray(i)[:, 0], np.arange(6))
        # the final stage rescored at full precision: score of the hit is
        # the exact rank-equivalent self-distance -||x||^2
        expect = -np.sum(np.asarray(db[:6]) ** 2, axis=1)
        np.testing.assert_allclose(np.asarray(s)[:, 0], expect,
                                   rtol=1e-4, atol=1e-3)

    def test_oversample_recovers_adc_misses(self):
        """Widening the stage-0 pool improves recall vs exact search on the
        clustered workload (the knob the acceptance run leans on)."""
        from repro.core import truncated_search
        from repro.rag import make_clustered_corpus
        c = make_clustered_corpus(n_docs=2048, dim=64, n_queries=32,
                                  n_clusters=24, seed=5)
        db = jnp.asarray(c.db)
        q = jnp.asarray(c.queries)
        _, exact = truncated_search(q, db, dim=64, k=5, block_n=2048)
        sched = make_schedule(16, 64, 32, final_k=5)
        idx = build_pq_index(db, sched, m=4, n_codes=64)

        def recall(oversample):
            _, i = pq_progressive_search(q, idx, sched,
                                         oversample=oversample)
            return np.mean([
                len(set(map(int, a)) & set(map(int, b))) / 5
                for a, b in zip(np.asarray(i), np.asarray(exact))])

        r1, r8 = recall(1), recall(8)
        assert r8 >= r1
        assert r8 >= 0.9

    def test_metric_guard(self):
        db = _db(n=64)
        sched = make_schedule(8, 32, 16)
        idx = build_pq_index(db, sched, m=2)
        with pytest.raises(ValueError, match="rank-equivalent"):
            pq_progressive_search(db[:2], idx, sched, metric="cosine")


class TestQuantizedBackendCodecs:
    def _engine(self, codec, n_docs=200, **opts):
        from repro.engine import RetrievalEngine
        eng = RetrievalEngine(
            32, d_start=8, k0=16, buckets=(4,), capacity=64, block_n=64,
            backend="quantized",
            backend_opts={"codec": codec, "min_rebuild_rows": 16, **opts})
        db = np.random.default_rng(9).normal(
            size=(n_docs, 32)).astype(np.float32)
        eng.add_docs(db)
        return eng, db

    def test_bad_codec_rejected(self):
        with pytest.raises(ValueError, match="codec"):
            self._engine("fp4")

    def test_int8_kernel_flag_rejected(self):
        with pytest.raises(ValueError, match="codec='pq'"):
            self._engine("int8", use_kernel=True)

    def test_pq_m_must_divide(self):
        with pytest.raises(ValueError, match="does not divide"):
            self._engine("pq", pq_m=3)

    @pytest.mark.parametrize("codec", ["int8", "pq"])
    def test_appends_encoded_against_frozen_grid(self, codec):
        """Churn-aware maintenance: appended rows are encoded in place at
        safe points (coded_upto advances, the tail stays empty) and no
        rebuild fires below the churn threshold."""
        eng, db = self._engine(codec)
        eng.search(db[:1])                          # build
        state = eng.index_state
        n_rebuilds = eng.stats.n_rebuilds
        upto0 = state.data["coded_upto"]
        new = np.random.default_rng(1).normal(size=(8, 32)).astype(np.float32)
        ids = eng.add_docs(new)
        _, got = eng.search(new)                    # safe point absorbs
        np.testing.assert_array_equal(got[:, 0], ids)
        assert eng.index_state is state             # same state, mutated
        assert state.data["coded_upto"] == upto0 + 8
        assert eng.stats.n_rebuilds == n_rebuilds
        # absorbed rows rank at stage 0, not via the tail window
        assert eng.backend._tail_load(state, eng.store.stats()) == 0

    def test_encode_appends_off_rides_tail(self):
        eng, db = self._engine("pq", encode_appends=False)
        eng.search(db[:1])
        state = eng.index_state
        upto0 = state.data["coded_upto"]
        new = np.random.default_rng(2).normal(size=(4, 32)).astype(np.float32)
        ids = eng.add_docs(new)
        _, got = eng.search(new)                    # reachable via tail
        np.testing.assert_array_equal(got[:, 0], ids)
        assert state.data["coded_upto"] == upto0
        assert eng.backend._tail_load(state, eng.store.stats()) == 4

    def test_appends_past_block_capacity_ride_tail(self):
        """The code block is capacity-shaped: rows landing beyond it (the
        store grew) stay reachable through the tail window."""
        eng, db = self._engine("pq", n_docs=250)    # capacity grew to 256
        eng.search(db[:1])
        state = eng.index_state
        assert state.data["n_coded"] == 256
        new = np.random.default_rng(3).normal(
            size=(10, 32)).astype(np.float32)       # rows 250..260: 4 over
        ids = eng.add_docs(new)
        _, got = eng.search(new)
        np.testing.assert_array_equal(got[:, 0], ids)
        assert state.data["coded_upto"] == 256
        assert eng.backend._tail_load(state, eng.store.stats()) == 4

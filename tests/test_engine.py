"""Retrieval engine: bucketing correctness, mutable-corpus visibility, and
parity with direct progressive_search on a static corpus."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import progressive_search
from repro.engine import BucketPolicy, DocStore, RetrievalEngine

RNG = np.random.default_rng(7)
D = 32


def make_engine(n_docs=120, **kw):
    kw.setdefault("d_start", 8)
    kw.setdefault("k0", 16)
    kw.setdefault("buckets", (1, 2, 4, 8))
    kw.setdefault("capacity", 16)
    kw.setdefault("block_n", 64)
    db = RNG.normal(size=(n_docs, D)).astype(np.float32)
    eng = RetrievalEngine(D, **kw)
    eng.add_docs(db)
    return eng, db


class TestBucketPolicy:
    def test_bucket_for_rounds_up(self):
        p = BucketPolicy((1, 2, 4, 8))
        assert [p.bucket_for(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
        assert p.bucket_for(100) == 8          # oversized -> top bucket

    def test_plan_covers_exactly(self):
        p = BucketPolicy((2, 4, 8))
        for n in range(1, 40):
            plan = p.plan(n)
            assert sum(plan) >= n
            # all but the last batch are full top-size buckets
            assert all(b == 8 for b in plan[:-1])
            assert sum(plan) - n < 8           # bounded padding

    def test_invalid_ladders_rejected(self):
        with pytest.raises(ValueError):
            BucketPolicy(())
        with pytest.raises(ValueError):
            BucketPolicy((4, 2))
        with pytest.raises(ValueError):
            BucketPolicy((0, 2))


class TestDocStore:
    def test_ids_stable_and_growth_doubles(self):
        store = DocStore(D, (8, 16, 32), capacity=4)
        a = store.add(RNG.normal(size=(3, D)).astype(np.float32))
        b = store.add(RNG.normal(size=(10, D)).astype(np.float32))
        assert a.tolist() == [0, 1, 2]
        assert b.tolist() == list(range(3, 13))
        assert store.capacity == 16 and store.n_grows >= 1
        assert store.size == 13 and store.n_active == 13

    def test_delete_is_tombstone(self):
        store = DocStore(D, (8,), capacity=8)
        ids = store.add(RNG.normal(size=(5, D)).astype(np.float32))
        assert store.delete(ids[:2]) == 2
        assert store.delete(ids[:2]) == 0      # already dead
        assert store.n_active == 3
        assert not store.is_live(int(ids[0])) and store.is_live(int(ids[4]))
        assert store.delete([4, 4, 4]) == 1    # duplicate ids count once
        assert store.n_active == 2
        with pytest.raises(IndexError):
            store.delete([99])

    def test_prefix_norms_match_batch_build(self):
        from repro.core import build_index
        dims = (8, 16, 32)
        store = DocStore(D, dims, capacity=2)
        rows = RNG.normal(size=(9, D)).astype(np.float32)
        for r in rows:                          # one-at-a-time appends
            store.add(r)
        ref = build_index(jnp.asarray(rows), dims)
        np.testing.assert_allclose(
            np.asarray(store.sq_prefix[:9]), np.asarray(ref["sq_prefix"]),
            rtol=1e-5, atol=1e-5)


class TestEngineParity:
    def test_search_matches_direct_progressive(self):
        eng, db = make_engine()
        q = db[:11] + 0.01 * RNG.normal(size=(11, D)).astype(np.float32)
        es, ei = eng.search(q)
        rs, ri = progressive_search(jnp.asarray(q), jnp.asarray(db), eng.sched)
        np.testing.assert_array_equal(ei, np.asarray(ri))
        np.testing.assert_allclose(es, np.asarray(rs), rtol=1e-5, atol=1e-5)

    def test_results_independent_of_bucket_ladder(self):
        db = RNG.normal(size=(80, D)).astype(np.float32)
        q = db[:9] + 0.01 * RNG.normal(size=(9, D)).astype(np.float32)
        outs = []
        for buckets in [(1,), (4,), (1, 2, 4, 8), (16,)]:
            eng = RetrievalEngine(D, d_start=8, k0=16, buckets=buckets,
                                  capacity=80, block_n=64)
            eng.add_docs(db)
            outs.append(eng.search(q)[1])
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)

    def test_empty_batch_returns_empty(self):
        eng, _ = make_engine(n_docs=20)
        s, i = eng.search(np.zeros((0, D), np.float32))
        assert s.shape == (0, eng.out_k)
        assert i.shape == (0, eng.out_k)

    def test_single_stage_schedule_honors_final_k(self):
        # d_emb <= d_start collapses the schedule to one stage that keeps k0
        # candidates; the engine must still return final_k-wide results, with
        # the same width for empty and non-empty batches.
        eng = RetrievalEngine(8, d_start=32, k0=8, final_k=1,
                              capacity=16, buckets=(2,), block_n=16)
        db = RNG.normal(size=(10, 8)).astype(np.float32)
        eng.add_docs(db)
        s, i = eng.search(db[:2])
        assert s.shape == (2, 1) and i.shape == (2, 1)
        np.testing.assert_array_equal(i[:, 0], [0, 1])
        s0, i0 = eng.search(np.zeros((0, 8), np.float32))
        assert s0.shape == (0, 1) and i0.shape == (0, 1)

    def test_search_rejects_wrong_query_dim(self):
        eng, _ = make_engine(n_docs=20)
        with pytest.raises(ValueError):
            eng.search(np.zeros((2, D + 1), np.float32))

    def test_request_path_matches_batch_search(self):
        eng, db = make_engine()
        q = db[5:12] + 0.02 * RNG.normal(size=(7, D)).astype(np.float32)
        _, direct = eng.search(q)
        rids = [eng.submit(v) for v in q]
        assert eng.n_pending == 7
        done = eng.run_until_idle()
        assert done == 7 and eng.n_pending == 0
        got = np.stack([eng.poll(r).doc_ids for r in rids])
        np.testing.assert_array_equal(got, direct)
        from repro.engine import ResultEvicted
        with pytest.raises(ResultEvicted):     # results pop once; a second
            eng.poll(rids[0])                  # poll is "gone", not "wait"

    def test_each_bucket_shape_compiles_once(self):
        eng, db = make_engine()
        for _ in range(3):
            for n in (1, 3, 7):
                eng.search(db[:n])
        # 3 distinct buckets (1, 4, 8) at one capacity -> 3 compile events
        assert eng.stats.n_compiles == 0        # search() path counts...
        assert len(eng._seen_shapes) == 3


class TestMutableCorpus:
    def test_deleted_doc_never_returned(self):
        eng, db = make_engine()
        # query IS doc 17's embedding: without deletion it must win
        q = db[17:18]
        _, before = eng.search(q)
        assert before[0, 0] == 17
        eng.delete_docs([17])
        _, after = eng.search(q)
        assert 17 not in after
        # request path agrees
        rid = eng.submit(q[0])
        eng.run_until_idle()
        assert 17 not in eng.poll(rid).doc_ids

    def test_added_doc_becomes_visible(self):
        eng, db = make_engine(n_docs=60)
        new = RNG.normal(size=(1, D)).astype(np.float32) * 5.0
        [nid] = eng.add_docs(new)
        _, idx = eng.search(new)
        assert idx[0, 0] == nid

    def test_add_beyond_capacity_keeps_results_correct(self):
        eng = RetrievalEngine(D, d_start=8, k0=8, capacity=4,
                              buckets=(4,), block_n=32)
        db = RNG.normal(size=(50, D)).astype(np.float32)
        for i in range(0, 50, 10):              # five appends, several grows
            eng.add_docs(db[i:i + 10])
        assert eng.store.capacity >= 50 and eng.store.n_grows >= 3
        _, idx = eng.search(db[:4])
        np.testing.assert_array_equal(idx[:, 0], np.arange(4))

    def test_fully_deleted_corpus_returns_sentinel(self):
        eng, db = make_engine(n_docs=10)
        eng.delete_docs(np.arange(10))
        assert eng.n_docs == 0
        scores, idx = eng.search(db[:2])
        assert (idx == -1).all()
        assert np.isinf(scores).all()

    def test_empty_tail_capacity_never_leaks(self):
        # capacity > size: unpopulated (zero) rows must not be returned,
        # even for a zero query whose nearest vector is the zero row.
        eng = RetrievalEngine(D, d_start=8, k0=8, capacity=64,
                              buckets=(1,), block_n=64)
        db = (RNG.normal(size=(5, D)).astype(np.float32)
              + 10.0)                            # far from the origin
        eng.add_docs(db)
        _, idx = eng.search(np.zeros((1, D), np.float32))
        assert 0 <= idx[0, 0] < 5


class TestPipelineCorpusSync:
    """RAGPipeline must keep engine ids and doc_tokens rows aligned."""

    def _pipe(self):
        import jax
        import jax.numpy as jnp
        from repro.configs.base import LMConfig
        from repro.models import lm as LM
        from repro.rag import RAGPipeline
        from repro.rag.pipeline import mean_pool_embedder
        cfg = LMConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_head=16, d_ff=64, vocab=128,
                       param_dtype="float32", compute_dtype="float32",
                       remat=False)
        params = LM.init_lm(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(
            np.random.default_rng(0).integers(1, 128, (6, 5)), jnp.int32)
        db = mean_pool_embedder(params, cfg)(toks)
        return RAGPipeline(params, cfg, db, toks, d_start=4, k0=4), db, toks

    def test_add_docs_validates_before_mutating(self):
        pipe, db, toks = self._pipe()
        with pytest.raises(ValueError):        # count mismatch
            pipe.add_docs(np.asarray(db[:2]), np.asarray(toks[:1]))
        with pytest.raises(ValueError):        # width mismatch
            pipe.add_docs(np.asarray(db[:1]),
                          np.zeros((1, 9), np.int32))
        # failed validation must not have touched the engine
        assert pipe.engine.store.size == 6

    def test_sentinel_prepends_padding_not_doc0(self):
        import jax.numpy as jnp
        pipe, db, toks = self._pipe()
        prompts = pipe.assemble_prompts(
            jnp.asarray(toks[:1]), np.asarray([[-1]], np.int32))
        doc_len = toks.shape[1]
        assert (np.asarray(prompts)[0, :doc_len] == 0).all()

    def test_zero_doc_corpus_serves(self):
        import jax.numpy as jnp
        pipe, db, toks = self._pipe()
        pipe.delete_docs(list(range(6)))
        out = pipe.serve(jnp.asarray(toks[:1]), max_new_tokens=2)
        assert out["retrieved"][0, 0] == -1
        assert out["generated"].shape == (1, 2)

    def test_driver_path_matches_sync_path(self):
        import jax.numpy as jnp
        pipe, db, toks = self._pipe()
        q = jnp.asarray(toks[:3])
        _, sync_ids = pipe.retrieve(q)
        pipe.start_driver(max_wait_ms=0.5)
        try:
            _, driver_ids = pipe.retrieve(q)
            np.testing.assert_array_equal(driver_ids, sync_ids)
        finally:
            pipe.stop_driver()
        # driver gone: back to the synchronous path
        _, after = pipe.retrieve(q)
        np.testing.assert_array_equal(after, sync_ids)

    def test_driver_results_refreshed_when_compaction_races_delivery(self):
        """A compaction landing between a driver dispatch and the pipeline's
        gather must not leak pre-remap doc ids: retrieve() detects the stale
        store_generation and re-searches under engine.lock."""
        import jax.numpy as jnp
        pipe, db, toks = self._pipe()
        eng = pipe.engine
        pipe.start_driver(max_wait_ms=0.5)
        try:
            # interpose on the driver's dispatch: right after the batch runs
            # (results already stamped with the pre-compaction generation),
            # delete half the corpus and force the compaction+rebuild —
            # deterministic stand-in for a racing mutator thread
            orig, fired = eng.execute_batch, []

            def tampered(reqs):
                out = orig(reqs)
                if not fired:
                    fired.append(True)
                    eng.delete_docs([3, 4, 5])   # dead_frac 0.5 >= 0.3
                    eng.maybe_rebuild(force=True)
                return out

            eng.execute_batch = tampered
            try:
                _, ids = pipe.retrieve(jnp.asarray(toks[:3]))
            finally:
                eng.execute_batch = orig
            assert eng.stats.n_compactions == 1
            # ids must be post-remap: valid rows of the shrunken token table
            assert (ids < pipe.doc_tokens.shape[0]).all()
            _, expected = pipe.retrieve(jnp.asarray(toks[:3]))
            np.testing.assert_array_equal(ids, expected)
        finally:
            pipe.stop_driver()

    def test_conflicting_engine_args_rejected(self):
        import jax
        import jax.numpy as jnp
        from repro.engine import RetrievalEngine
        from repro.rag import RAGPipeline
        pipe, db, toks = self._pipe()
        params, cfg = pipe.lm_params, pipe.cfg
        eng = RetrievalEngine(db.shape[1], d_start=4, k0=4, capacity=8)
        with pytest.raises(ValueError):
            RAGPipeline(params, cfg, db, toks, engine=eng, buckets=(64,))


class TestStatsAndProfile:
    def test_request_stats_fields(self):
        eng, db = make_engine()
        eng.search(db[:1])                     # warm the bucket-1 shape
        rid = eng.submit(db[0])
        eng.step()
        res = eng.poll(rid)
        st = res.stats
        assert not st.compiled
        assert st.latency_ms >= st.queue_ms >= 0
        assert st.compute_ms > 0
        assert st.bucket >= st.batch_fill == 1
        s = eng.stats.summary()
        assert s["n_completed"] == 1 and s["n_batches"] == 1
        assert np.isfinite(s["latency_ms_p50"])

    def test_compiled_batches_excluded_from_percentiles(self):
        eng, db = make_engine()
        rid = eng.submit(db[0])                # cold shape: compile event
        eng.step()
        assert eng.poll(rid).stats.compiled
        s = eng.stats.summary()
        assert s["n_compiles"] == 1 and s["n_completed"] == 1
        assert not np.isfinite(s["latency_ms_p50"])  # no steady samples yet

    def test_submit_rejects_matrix_query(self):
        eng, db = make_engine()
        with pytest.raises(ValueError):        # (4, 8) flattens to D=32 but
            eng.submit(db[0].reshape(4, 8))    # is not a query vector
        eng.submit(db[0:1])                    # (1, D) is accepted

    def test_padding_accounted(self):
        eng, db = make_engine()
        for v in db[:3]:
            eng.submit(v)
        eng.run_until_idle()
        # 3 requests -> one bucket-4 batch with 1 padded slot
        assert eng.stats.n_padded_slots == 1

    def test_profile_stages_covers_schedule(self):
        eng, db = make_engine()
        prof = eng.profile_stages(db[:2], runs=1)
        assert [p["dim"] for p in prof] == [s.dim for s in eng.sched.stages]
        assert all(p["ms"] >= 0 for p in prof)

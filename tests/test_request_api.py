"""Typed request/config API contract: `SearchRequest` normalization and
per-request options, filter canonicalization, `EngineConfig` validation +
serialization + back-compat shims, the three-way ``poll`` semantics, and
the store's epoch-checked mask cache."""

import argparse
import dataclasses

import numpy as np
import pytest

from repro.engine import (
    DeadlineExceeded,
    EngineConfig,
    EngineDriver,
    FilterError,
    FlatConfig,
    IVFConfig,
    QuantizedConfig,
    ResultEvicted,
    RetrievalEngine,
    SearchRequest,
    UnknownRequest,
    backend_config,
    canonical_filter,
)

D = 24
RNG = np.random.default_rng(9)


def make_engine(**kw):
    kw.setdefault("d_start", 8)
    kw.setdefault("k0", 8)
    kw.setdefault("final_k", 4)
    kw.setdefault("buckets", (2,))
    kw.setdefault("capacity", 32)
    kw.setdefault("block_n", 32)
    eng = RetrievalEngine(D, **kw)
    db = RNG.normal(size=(20, D)).astype(np.float32)
    eng.add_docs(db)
    return eng, db


class TestCanonicalFilter:
    def test_none_and_empty_are_none(self):
        assert canonical_filter(None) is None
        assert canonical_filter({}) is None

    def test_shorthand_equals_explicit_eq(self):
        assert canonical_filter({"f": 3}) == canonical_filter(
            {"f": {"$eq": 3}})

    def test_order_insensitive(self):
        a = canonical_filter({"a": 1, "b": {"$gte": 2, "$lt": 9}})
        b = canonical_filter({"b": {"$lt": 9, "$gte": 2}, "a": 1})
        assert a == b and hash(a) == hash(b)

    def test_unknown_op_raises(self):
        with pytest.raises(FilterError, match=r"\$regex"):
            canonical_filter({"f": {"$regex": ".*"}})

    def test_non_scalar_value_raises(self):
        with pytest.raises(FilterError):
            canonical_filter({"f": {"$eq": [1, 2]}})

    def test_in_requires_sequence(self):
        with pytest.raises(FilterError):
            canonical_filter({"f": {"$in": 3}})


class TestSearchRequest:
    def test_raw_array_equals_search_request(self):
        eng, db = make_engine()
        r1 = eng.submit(db[5])
        r2 = eng.submit(SearchRequest(db[5]))
        eng.run_until_idle()
        a, b = eng.poll(r1), eng.poll(r2)
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)

    def test_per_request_k_slices_results(self):
        eng, db = make_engine()
        rid = eng.submit(SearchRequest(db[2], k=2))
        eng.run_until_idle()
        res = eng.poll(rid)
        assert res.doc_ids.shape == (2,) and res.scores.shape == (2,)
        assert res.doc_ids[0] == 2

    def test_k_out_of_range_rejected(self):
        eng, db = make_engine()                   # out_k == final_k == 4
        with pytest.raises(ValueError, match="k=9"):
            eng.submit(SearchRequest(db[0], k=9))
        with pytest.raises(ValueError, match="k"):
            eng.submit(SearchRequest(db[0], k=0))

    def test_mask_key_identity(self):
        r = SearchRequest(np.zeros(4), tenant="t",
                          filter={"a": 1, "b": {"$lt": 2}})
        same = SearchRequest(np.ones(4), tenant="t",
                             filter={"b": {"$lt": 2}, "a": {"$eq": 1}})
        assert r.mask_key() == same.mask_key()
        assert SearchRequest(np.zeros(4)).mask_key() is None

    def test_tenant_scoped_submit(self):
        eng, db = make_engine()
        extra = RNG.normal(size=(4, D)).astype(np.float32)
        ids = eng.add_docs(extra, tenant="mine")
        rid = eng.submit(SearchRequest(extra[0], tenant="mine"))
        eng.run_until_idle()
        res = eng.poll(rid)
        got = set(int(i) for i in res.doc_ids if i >= 0)
        assert got and got <= set(ids.tolist())

    def test_mixed_mask_keys_split_into_homogeneous_batches(self):
        """One step() never mixes constraint groups; every request still
        completes with its own constraint applied (FIFO, no starvation)."""
        eng, db = make_engine()
        a_ids = eng.add_docs(RNG.normal(size=(3, D)).astype(np.float32),
                             tenant="a")
        rids = [eng.submit(db[0]),
                eng.submit(SearchRequest(db[0], tenant="a")),
                eng.submit(db[1]),
                eng.submit(SearchRequest(db[1], tenant="a"))]
        eng.run_until_idle()
        plain0 = eng.poll(rids[0])
        scoped0 = eng.poll(rids[1])
        assert plain0.doc_ids[0] == 0
        assert set(int(i) for i in scoped0.doc_ids
                   if i >= 0) <= set(a_ids.tolist())
        assert eng.poll(rids[2]).doc_ids[0] == 1
        assert eng.stats.n_batches >= 2


class TestPollSemantics:
    def test_unknown_id_raises(self):
        eng, _ = make_engine()
        with pytest.raises(UnknownRequest):
            eng.poll(999)

    def test_pending_returns_none(self):
        eng, db = make_engine()
        rid = eng.submit(db[0])
        assert eng.poll(rid) is None              # queued, batch not run

    def test_double_poll_raises_evicted(self):
        eng, db = make_engine()
        rid = eng.submit(db[0])
        eng.run_until_idle()
        assert eng.poll(rid) is not None
        with pytest.raises(ResultEvicted):
            eng.poll(rid)

    def test_overflow_eviction_raises_evicted(self):
        eng, db = make_engine(max_unpolled=2)
        rids = [eng.submit(db[i]) for i in range(4)]
        eng.run_until_idle()
        with pytest.raises(ResultEvicted):
            eng.poll(rids[0])                     # oldest: evicted past cap
        assert eng.poll(rids[3]) is not None      # newest survives


class TestDriverRequests:
    def test_search_request_through_driver(self):
        eng, db = make_engine()
        ids = eng.add_docs(RNG.normal(size=(3, D)).astype(np.float32),
                           tenant="drv")
        with EngineDriver(eng, max_wait_ms=0.0) as driver:
            res = driver.retrieve(
                SearchRequest(db[0], k=1), timeout=30.0)
            assert res.doc_ids.shape == (1,) and res.doc_ids[0] == 0
            scoped = driver.retrieve(
                SearchRequest(db[0], tenant="drv"), timeout=30.0)
            got = set(int(i) for i in scoped.doc_ids if i >= 0)
            assert got and got <= set(ids.tolist())

    def test_expired_deadline_fails_future(self):
        eng, db = make_engine()
        with EngineDriver(eng, max_wait_ms=0.0) as driver:
            fut = driver.submit(SearchRequest(db[0], deadline_ms=1e-4))
            with pytest.raises(DeadlineExceeded):
                fut.result(30.0)
            assert driver.stats.n_expired == 1
            # the driver keeps serving after shedding
            assert driver.retrieve(db[0], timeout=30.0).doc_ids[0] == 0


class TestMaskCache:
    def test_cache_hit_until_epoch_bump(self):
        eng, _ = make_engine()
        eng.add_docs(RNG.normal(size=(2, D)).astype(np.float32), tenant="c")
        key = eng.store.compile_mask("c", None)
        m1 = eng.store.mask_for_key(key)
        assert eng.store.mask_for_key(key) is m1  # cached, same epoch
        eng.add_docs(RNG.normal(size=(1, D)).astype(np.float32), tenant="c")
        m2 = eng.store.mask_for_key(key)
        assert m2 is not m1                       # append invalidated it
        assert int(m2.sum()) == 3

    def test_mask_tracks_capacity_growth(self):
        eng, _ = make_engine(capacity=32)
        key = eng.store.compile_mask("g", None)
        assert eng.store.mask_for_key(key).shape == (32,)
        eng.add_docs(RNG.normal(size=(40, D)).astype(np.float32),
                     tenant="g")                  # forces buffer doubling
        mask = eng.store.mask_for_key(key)
        assert mask.shape == (eng.store.capacity,)
        assert int(mask.sum()) == 40

    def test_delete_does_not_invalidate(self):
        # tombstones are covered by the validity AND at dispatch; the mask
        # cache must NOT churn on every delete
        eng, _ = make_engine()
        ids = eng.add_docs(RNG.normal(size=(3, D)).astype(np.float32),
                           tenant="d")
        key = eng.store.compile_mask("d", None)
        m1 = eng.store.mask_for_key(key)
        eng.delete_docs(ids[:1])
        assert eng.store.mask_for_key(key) is m1


class TestEngineConfig:
    def test_round_trip(self):
        cfg = EngineConfig(
            d_emb=64, d_start=16, k0=16, final_k=4, buckets=(1, 4),
            capacity=128, backend=IVFConfig(n_lists=8, n_probe=4))
        again = EngineConfig.from_dict(cfg.to_dict())
        assert again == cfg

    def test_validation_eager(self):
        with pytest.raises(ValueError, match="d_start"):
            EngineConfig(d_emb=8, d_start=16)
        with pytest.raises(ValueError, match="buckets"):
            EngineConfig(d_emb=8, d_start=8, buckets=(4, 2))
        with pytest.raises(ValueError, match="metric"):
            EngineConfig(d_emb=8, d_start=8, metric="dot")
        with pytest.raises(ValueError, match="stage0_dtype"):
            IVFConfig(stage0_dtype="fp4")
        with pytest.raises(ValueError, match="codec"):
            QuantizedConfig(codec="gzip")

    def test_backend_config_rejects_unknowns(self):
        with pytest.raises(ValueError, match="unknown index backend"):
            backend_config("hnsw")
        with pytest.raises(ValueError, match="option"):
            backend_config("ivf", {"n_lists": 4, "bogus_knob": 1})

    def test_config_path_equals_legacy_path(self):
        cfg = EngineConfig(d_emb=D, d_start=8, k0=8, final_k=4,
                           buckets=(2,), capacity=32, block_n=32,
                           backend=FlatConfig())
        via_config = RetrievalEngine(config=cfg)
        via_legacy, _ = make_engine()
        assert via_config.config == via_legacy.config

    def test_legacy_backend_opts_still_work(self):
        eng = RetrievalEngine(D, d_start=8, k0=8, buckets=(2,), capacity=32,
                              backend="ivf",
                              backend_opts={"n_lists": 4, "n_probe": 2})
        assert isinstance(eng.config.backend, IVFConfig)
        assert eng.config.backend.n_lists == 4

    def test_legacy_bad_option_rejected_eagerly(self):
        with pytest.raises(ValueError, match="option"):
            RetrievalEngine(D, backend="ivf",
                            backend_opts={"n_listz": 4})

    def test_config_conflicts_rejected(self):
        cfg = EngineConfig(d_emb=D, d_start=8)
        with pytest.raises(ValueError, match="conflicts"):
            RetrievalEngine(config=cfg, k0=16)
        with pytest.raises(ValueError, match="conflicts"):
            RetrievalEngine(64, config=cfg)

    def test_from_flags(self):
        ap = argparse.ArgumentParser()
        EngineConfig.add_flags(ap)
        args = ap.parse_args(["--backend", "quantized", "--codec", "pq",
                              "--final-k", "4", "--buckets", "1,2"])
        cfg = EngineConfig.from_flags(args, d_emb=64, capacity=256)
        assert isinstance(cfg.backend, QuantizedConfig)
        assert cfg.backend.codec == "pq"
        assert cfg.final_k == 4 and cfg.buckets == (1, 2)
        assert cfg.capacity == 256

    def test_engine_reports_config(self):
        eng, _ = make_engine()
        d = eng.config.to_dict()
        assert d["d_emb"] == D and d["backend"]["backend"] == "flat"
        # frozen: the reported config can't be mutated out from under the
        # engine
        with pytest.raises(dataclasses.FrozenInstanceError):
            eng.config.d_emb = 1

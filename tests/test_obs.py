"""Observability spine: metrics registry, trace spans, slow-query log.

Covers the `repro.obs` unit surface (label cardinality caps, histogram
bucket math, concurrent increments, Prometheus exposition golden format)
and the wired engine/driver behaviour: span monotonicity under a racing
add/delete workload, slow-query logging via an injected sleepy backend,
and an 8-thread stats hammer that reconciles every counter against the
number of results actually delivered.
"""

import threading
import time

import numpy as np
import pytest

from repro.engine import EngineDriver, RetrievalEngine, SearchRequest
from repro.engine.config import ObsConfig
from repro.index_backends.flat import FlatProgressiveBackend
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_MS,
    MARK_ORDER,
    MetricsRegistry,
    NULL_INSTRUMENT,
    SlowQueryLog,
    TraceContext,
    TraceRing,
    histogram_counts,
    parse_prometheus,
    percentile_from_counts,
    summarize_latency,
)

RNG = np.random.default_rng(7)
D = 16
WAIT = 30.0


def make_engine(n_docs=64, **kw):
    kw.setdefault("d_start", 4)
    kw.setdefault("k0", 8)
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("capacity", 256)
    kw.setdefault("block_n", 32)
    eng = RetrievalEngine(D, **kw)
    db = RNG.normal(size=(n_docs, D)).astype(np.float32)
    eng.add_docs(db)
    return eng, db


# -- registry ---------------------------------------------------------------

class TestRegistry:
    def test_counter_inc_and_value(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total", "help")
        c.inc()
        c.inc(2.5)
        assert c.value() == pytest.approx(3.5)

    def test_counter_rejects_decrease(self):
        c = MetricsRegistry().counter("x_total")
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1)

    def test_label_names_enforced(self):
        c = MetricsRegistry().counter("x_total", labels=("tenant",))
        with pytest.raises(ValueError, match="takes labels"):
            c.inc(route="/v1/search")
        with pytest.raises(ValueError, match="takes labels"):
            c.inc()                       # missing label entirely

    def test_duplicate_registration_must_match(self):
        reg = MetricsRegistry()
        c1 = reg.counter("x_total", labels=("a",))
        assert reg.counter("x_total", labels=("a",)) is c1
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x_total", labels=("a",))
        with pytest.raises(ValueError, match="already registered"):
            reg.counter("x_total", labels=("b",))

    def test_cardinality_cap_collapses_to_overflow(self):
        reg = MetricsRegistry(max_series=2)
        c = reg.counter("t_total", "per-tenant", labels=("tenant",))
        c.inc(tenant="a")
        c.inc(tenant="b")
        c.inc(tenant="c")                 # past the cap
        c.inc(tenant="d")
        c.inc(tenant="a")                 # existing series still direct
        assert c.value(tenant="a") == 2.0
        parsed = parse_prometheus(reg.render_prometheus())
        series = parsed["t_total"]
        assert series[(("tenant", "a"),)] == 2.0
        assert series[(("tenant", "__overflow__"),)] == 2.0
        assert (("tenant", "c"),) not in series

    def test_disabled_registry_hands_out_noop(self):
        reg = MetricsRegistry(enabled=False)
        c = reg.counter("x_total")
        assert c is NULL_INSTRUMENT
        c.inc()
        c.observe(1.0)
        assert c.value() == 0.0
        assert reg.render_prometheus().strip() == ""

    def test_concurrent_increments_lose_nothing(self):
        reg = MetricsRegistry()
        c = reg.counter("hits_total", labels=("tenant",))
        h = reg.histogram("lat_ms", buckets=(1.0, 10.0))
        n_threads, per_thread = 8, 500

        def worker(tid):
            for i in range(per_thread):
                c.inc(tenant=f"t{tid % 2}")
                h.observe(float(i % 20))

        ts = [threading.Thread(target=worker, args=(t,))
              for t in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(WAIT)
        total = c.value(tenant="t0") + c.value(tenant="t1")
        assert total == n_threads * per_thread
        assert h.count() == n_threads * per_thread


class TestHistogram:
    def test_bucket_math_matches_offline_helper(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat_ms", buckets=(1.0, 5.0, 25.0))
        values = [0.2, 1.0, 1.1, 4.9, 5.0, 30.0, 100.0]
        for v in values:
            h.observe(v)
        snap = reg.snapshot()["lat_ms"]["series"][""]
        assert snap["counts"] == histogram_counts(values, (1.0, 5.0, 25.0))
        assert snap["count"] == len(values)
        assert snap["sum"] == pytest.approx(sum(values))

    def test_observe_on_bucket_boundary_counts_le(self):
        # Prometheus buckets are `le` (inclusive upper bound)
        counts = histogram_counts([1.0], (1.0, 5.0))
        assert counts == [1, 0, 0]

    def test_percentile_interpolation(self):
        buckets = (10.0, 20.0)
        counts = [10, 10, 0]              # uniform halves, nothing in +Inf
        assert percentile_from_counts(counts, buckets, 50.0) == \
            pytest.approx(10.0)
        assert percentile_from_counts(counts, buckets, 75.0) == \
            pytest.approx(15.0)
        assert percentile_from_counts(counts, buckets, 100.0) == \
            pytest.approx(20.0)

    def test_percentile_empty_is_nan(self):
        import math
        assert math.isnan(percentile_from_counts([0, 0], (1.0,), 50.0))

    def test_summarize_latency_keys_and_consistency(self):
        values = [float(v) for v in RNG.uniform(0.5, 400.0, size=200)]
        s = summarize_latency(values)
        assert set(s) == {"p50", "p95"}
        counts = histogram_counts(values)
        assert s["p95"] == pytest.approx(percentile_from_counts(
            counts, DEFAULT_LATENCY_BUCKETS_MS, 95.0))
        assert s["p50"] <= s["p95"]

    def test_bad_buckets_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="ascending"):
            reg.histogram("h", buckets=(5.0, 1.0))


class TestPrometheusExposition:
    def test_golden_format(self):
        reg = MetricsRegistry()
        reg.counter("req_total", "requests served",
                    labels=("route",)).inc(3, route="/v1/search")
        reg.gauge("depth", "queue depth").set(7)
        h = reg.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
        h.observe(0.5)
        h.observe(2.0)
        h.observe(99.0)
        text = reg.render_prometheus()
        assert text.splitlines() == [
            "# HELP depth queue depth",
            "# TYPE depth gauge",
            "depth 7",
            "# HELP lat_ms latency",
            "# TYPE lat_ms histogram",
            'lat_ms_bucket{le="1"} 1',
            'lat_ms_bucket{le="10"} 2',
            'lat_ms_bucket{le="+Inf"} 3',
            "lat_ms_sum 101.5",
            "lat_ms_count 3",
            "# HELP req_total requests served",
            "# TYPE req_total counter",
            'req_total{route="/v1/search"} 3',
        ]

    def test_parse_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("a_total", labels=("x", "y")).inc(2, x="u,v", y="w")
        reg.histogram("h_ms", buckets=(1.0,)).observe(0.5)
        parsed = parse_prometheus(reg.render_prometheus())
        assert parsed["a_total"][(("x", "u,v"), ("y", "w"))] == 2.0
        assert parsed["h_ms_count"][()] == 1.0
        assert parsed["h_ms_bucket"][(("le", "+Inf"),)] == 1.0

    def test_parse_rejects_malformed(self):
        with pytest.raises(ValueError, match="malformed"):
            parse_prometheus("what even is this line {")
        with pytest.raises(ValueError, match="malformed"):
            parse_prometheus('m{l=unquoted} 1')


# -- trace primitives -------------------------------------------------------

class TestTrace:
    def test_spans_are_offsets_in_pipeline_order(self):
        tr = TraceContext(100.0)
        tr.mark("deliver", 100.5)         # insertion order != pipeline order
        tr.mark("dispatch", 100.2)
        spans = tr.spans_ms()
        assert list(spans) == ["submit", "dispatch", "deliver"]
        assert spans["submit"] == 0.0
        assert spans["dispatch"] == pytest.approx(200.0)
        assert spans["deliver"] == pytest.approx(500.0)
        assert list(spans) == [m for m in MARK_ORDER if m in spans]

    def test_ring_bounded_most_recent_kept(self):
        ring = TraceRing(capacity=4)
        for i in range(10):
            ring.push({"request_id": i})
        assert len(ring) == 4
        assert [r["request_id"] for r in ring.snapshot()] == [6, 7, 8, 9]
        assert [r["request_id"] for r in ring.snapshot(2)] == [8, 9]

    def test_ring_zero_capacity_drops_everything(self):
        ring = TraceRing(capacity=0)
        ring.push({"request_id": 1})
        assert len(ring) == 0 and ring.snapshot() == []

    def test_slow_log_thresholds(self):
        log = SlowQueryLog(threshold_ms=10.0)
        assert not log.maybe_log({"latency_ms": 9.9})
        assert log.maybe_log({"latency_ms": 10.0, "request_id": 5})
        assert log.n_logged == 1
        rec = log.recent()[0]
        assert rec["request_id"] == 5
        assert rec["slow_query_threshold_ms"] == 10.0

    def test_slow_log_disabled_by_none(self):
        log = SlowQueryLog(threshold_ms=None)
        assert not log.enabled
        assert not log.maybe_log({"latency_ms": 1e9})
        assert log.n_logged == 0


# -- engine wiring ----------------------------------------------------------

class TestEngineObs:
    def test_search_results_carry_spans(self):
        eng, db = make_engine()
        rid = eng.submit(db[3])
        eng.run_until_idle()
        res = eng.poll(rid)
        spans = res.stats.spans
        assert spans is not None
        for name in ("submit", "admit", "batch", "dispatch", "deliver"):
            assert name in spans
        ordered = [spans[m] for m in MARK_ORDER if m in spans]
        assert ordered == sorted(ordered)
        assert res.stats.stage0_ms is None          # fused fast path
        assert res.stats.rescore_ms is None

    def test_stage_fences_split_compute(self):
        eng, db = make_engine(obs=ObsConfig(stage_fences=True))
        plain = RetrievalEngine(D, d_start=4, k0=8, buckets=(1, 2, 4),
                                capacity=256, block_n=32)
        plain.add_docs(db)
        rid = eng.submit(db[5])
        eng.run_until_idle()
        res = eng.poll(rid)
        assert res.stats.stage0_ms is not None
        assert res.stats.rescore_ms is not None
        assert res.stats.stage0_ms + res.stats.rescore_ms == \
            pytest.approx(res.stats.compute_ms, rel=0.05, abs=0.5)
        assert {"stage0", "rescore"} <= set(res.stats.spans)
        # the fenced path returns the same top hit as the fused path
        rid2 = plain.submit(db[5])
        plain.run_until_idle()
        assert res.doc_ids[0] == plain.poll(rid2).doc_ids[0] == 5

    def test_metrics_surface_covers_components(self):
        eng, db = make_engine()
        for i in range(5):
            eng.submit(db[i])
        eng.run_until_idle()
        text = eng.metrics.render_prometheus()
        parsed = parse_prometheus(text)
        assert parsed["repro_engine_requests_completed_total"][()] == 5.0
        assert parsed["repro_engine_request_latency_ms_count"][()] == 5.0
        assert parsed["repro_engine_queue_depth"][()] == 0.0
        store = {k[0][1]: v for k, v in
                 parsed["repro_store_state"].items()}
        assert store["n_active"] == 64.0
        assert store["capacity"] == 256.0
        # the flat backend declares no gauges, but the family is exposed
        assert "# TYPE repro_backend_state gauge" in text
        # counters stay reconciled with the legacy stats surface
        s = eng.stats.summary()
        assert parsed["repro_engine_batches_total"][()] == s["n_batches"]

    def test_ivf_backend_gauges_published(self):
        eng = RetrievalEngine(
            D, d_start=4, k0=8, buckets=(1, 2, 4), capacity=256,
            block_n=32, backend="ivf",
            backend_opts=dict(n_lists=8, n_probe=4, min_index_rows=16,
                              min_rebuild_rows=16))
        db = RNG.normal(size=(64, D)).astype(np.float32)
        eng.add_docs(db)
        eng.submit(db[0])
        eng.run_until_idle()
        parsed = parse_prometheus(eng.metrics.render_prometheus())
        series = parsed["repro_backend_state"]
        assert {dict(k)["backend"] for k in series} == {"ivf"}
        gauges = {dict(k)["key"]: v for k, v in series.items()}
        assert gauges["built_size"] == 64.0
        assert {"tail_load", "tail_cap", "staleness_rows"} <= set(gauges)

    def test_trace_ring_collects_requests(self):
        eng, db = make_engine(obs=ObsConfig(trace_ring=3))
        for i in range(7):
            eng.submit(db[i])
            eng.run_until_idle()
        assert len(eng.trace_ring) == 3
        last = eng.trace_ring.snapshot()[-1]
        assert {"request_id", "latency_ms", "spans"} <= set(last)

    def test_obs_disabled_restores_bare_path(self):
        eng, db = make_engine(obs=ObsConfig(enabled=False))
        rid = eng.submit(db[0])
        eng.run_until_idle()
        res = eng.poll(rid)
        assert res.stats.spans is None              # no TraceContext at all
        assert len(eng.trace_ring) == 0
        assert eng.metrics.render_prometheus().strip() == ""
        # the legacy stats surface still works
        assert eng.stats.summary()["n_completed"] == 1


class SleepyBackend(FlatProgressiveBackend):
    """Flat backend with a host-side stall injected into every search —
    drives real per-dispatch latency for the slow-query-log test."""

    def __init__(self, *args, sleep_s=0.02, **kw):
        super().__init__(*args, **kw)
        self.sleep_s = sleep_s

    def search(self, *args, **kw):
        time.sleep(self.sleep_s)
        return super().search(*args, **kw)


class TestSlowQueryLog:
    def test_sleepy_backend_trips_the_log(self):
        from repro.core import make_schedule

        sched = make_schedule(4, D, 8, final_k=1)
        backend = SleepyBackend(sched, metric="l2", block_n=32,
                                sleep_s=0.02)
        eng = RetrievalEngine(
            D, d_start=4, k0=8, buckets=(1, 2, 4), capacity=256,
            block_n=32, backend=backend,
            obs=ObsConfig(slow_query_ms=5.0))
        db = RNG.normal(size=(32, D)).astype(np.float32)
        eng.add_docs(db)
        for i in range(3):
            eng.submit(db[i])
            eng.run_until_idle()
        assert eng.slow_log.n_logged == 3
        recent = eng.slow_log.recent()
        assert all(r["latency_ms"] >= 5.0 for r in recent)
        assert all(r["slow_query_threshold_ms"] == 5.0 for r in recent)
        assert eng.metrics.counter(
            "repro_slow_queries_total").value() == 3.0

    def test_fast_requests_stay_unlogged(self):
        eng, db = make_engine(obs=ObsConfig(slow_query_ms=60_000.0))
        eng.submit(db[0])
        eng.run_until_idle()
        assert eng.slow_log.n_logged == 0
        assert eng.metrics.counter(
            "repro_slow_queries_total").value() == 0.0


# -- driver wiring ----------------------------------------------------------

class TestDriverObs:
    def test_span_monotonicity_under_racing_churn(self):
        eng, db = make_engine(n_docs=64, capacity=512)
        stop = threading.Event()

        def churn():
            i = 0
            while not stop.is_set():
                ids = eng.add_docs(
                    RNG.normal(size=(2, D)).astype(np.float32))
                eng.delete_docs(ids)
                i += 1
                time.sleep(0.001)

        churn_t = threading.Thread(target=churn)
        churn_t.start()
        try:
            with EngineDriver(eng, max_wait_ms=2.0) as driver:
                results = [driver.retrieve(db[i % 64], timeout=WAIT)
                           for i in range(24)]
        finally:
            stop.set()
            churn_t.join(WAIT)
        for res in results:
            spans = res.stats.spans
            assert spans is not None
            for name in ("submit", "admit", "batch", "dispatch", "deliver"):
                assert name in spans, f"missing {name}: {spans}"
            ordered = [spans[m] for m in MARK_ORDER if m in spans]
            assert ordered == sorted(ordered), spans
            assert spans["submit"] == 0.0
            assert spans["deliver"] == pytest.approx(
                res.stats.latency_ms, rel=1e-6, abs=1e-6)
        parsed = parse_prometheus(eng.metrics.render_prometheus())
        assert parsed["repro_driver_queue_wait_ms_count"][()] == 24.0
        assert parsed["repro_driver_requests_submitted_total"][()] == 24.0

    def test_stats_hammer_reconciles_exactly(self):
        """8 threads hammering submit/result; every total must equal the
        number of results actually delivered — no lost or double counts.
        Half the traffic is tenant-filtered so the store's mask-cache
        counters race the scrapes too (no torn reads: plain ints under
        engine.lock, mirrored whole at collect time)."""
        eng, db = make_engine(n_docs=64, capacity=256)
        eng.add_docs(RNG.normal(size=(16, D)).astype(np.float32),
                     tenant="obs")
        n_threads, per_thread = 8, 16
        delivered = []
        lock = threading.Lock()
        errors = []

        def client(tid):
            try:
                out = []
                for i in range(per_thread):
                    q = db[(tid * 7 + i) % 64]
                    req = (SearchRequest(q, tenant="obs") if i % 2
                           else q)
                    out.append(driver.retrieve(req, timeout=WAIT))
                with lock:
                    delivered.extend(out)
            except Exception as e:          # pragma: no cover - diagnostic
                errors.append(e)

        with EngineDriver(eng, max_wait_ms=1.0) as driver:
            ts = [threading.Thread(target=client, args=(t,))
                  for t in range(n_threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(WAIT)
        assert not errors
        total = n_threads * per_thread
        assert len(delivered) == total
        assert all(r.stats.spans is not None for r in delivered)

        s = eng.stats.summary()
        ds = driver.stats.summary()
        assert s["n_submitted"] == s["n_completed"] == total
        assert ds["n_submitted"] == ds["n_completed"] == total
        assert ds["n_cancelled"] == ds["n_expired"] == 0

        parsed = parse_prometheus(eng.metrics.render_prometheus())
        assert parsed["repro_engine_requests_submitted_total"][()] == total
        assert parsed["repro_engine_requests_completed_total"][()] == total
        assert parsed["repro_engine_request_latency_ms_count"][()] == total
        assert parsed["repro_engine_request_queue_ms_count"][()] == total
        assert parsed["repro_driver_requests_completed_total"][()] == total
        assert parsed["repro_driver_queue_wait_ms_count"][()] == total
        # batch accounting: bucket-labelled flushes sum to the batch total
        flushes = sum(parsed["repro_driver_flush_total"].values())
        assert flushes == s["n_batches"]
        fills = sum(parsed["repro_engine_batch_bucket_total"].values())
        assert fills == s["n_batches"]
        # mask-cache counters: one key ("obs", no filter) and no epoch
        # bump mid-hammer => exactly one compile; the prometheus mirror
        # must equal the plain ints exactly (scrape-time set_total — a
        # torn read would show partial totals here)
        with eng.lock:
            mc = eng.store.mask_cache_stats()
        assert mc["misses"] == 1
        assert mc["hits"] >= 1
        assert mc["entries"] == 1
        assert parsed["repro_store_mask_cache_hits_total"][()] == mc["hits"]
        assert (parsed["repro_store_mask_cache_misses_total"][()]
                == mc["misses"])

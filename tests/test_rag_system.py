"""End-to-end RAG system behaviour (the paper's pipeline, Fig. 1/2)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import LMConfig
from repro.core import make_schedule, top1_accuracy, truncated_search, progressive_search
from repro.models import lm as LM
from repro.rag import RAGPipeline, make_corpus

TINY = LMConfig(name="tiny-rag", n_layers=2, d_model=48, n_heads=4,
                n_kv_heads=2, d_head=12, d_ff=96, vocab=512,
                param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(n_docs=5000, dim=256, n_queries=200, seed=1)


class TestCorpusStatistics:
    def test_accuracy_monotone_in_dim(self, corpus):
        db = jnp.asarray(corpus.db)
        q = jnp.asarray(corpus.queries)
        gt = jnp.asarray(corpus.ground_truth)
        accs = []
        for d in (16, 64, 256):
            _, i = truncated_search(q, db, dim=d, k=1)
            accs.append(float(top1_accuracy(i, gt)))
        assert accs[0] < accs[1] <= accs[2] + 0.02
        assert accs[2] > 0.8          # plateau high but not perfect
        assert accs[2] < 1.0          # twins keep it below 100%

    def test_progressive_preserves_full_dim_accuracy(self, corpus):
        """Paper Table III: matched accuracy at the same d_max."""
        db = jnp.asarray(corpus.db)
        q = jnp.asarray(corpus.queries)
        gt = jnp.asarray(corpus.ground_truth)
        _, t = truncated_search(q, db, dim=256, k=1)
        # matched-accuracy config: generous Ds and K, as the paper's Table
        # III rows for high target accuracy (Ds up to 512 of 3584; our
        # heavy-tailed query-noise corpus needs Ds=Dm/2 for the last ~2%)
        sched = make_schedule(128, 256, 128)
        _, p = progressive_search(q, db, sched)
        acc_t = float(top1_accuracy(t, gt))
        acc_p = float(top1_accuracy(p, gt))
        assert abs(acc_t - acc_p) < 0.02, (acc_t, acc_p)


class TestRAGPipeline:
    def test_serve_batched_requests(self, corpus):
        rng = np.random.default_rng(0)
        params = LM.init_lm(jax.random.PRNGKey(0), TINY)
        n_docs = 64
        doc_tokens = jnp.asarray(
            rng.integers(1, TINY.vocab, (n_docs, 12)), jnp.int32)
        # embeddings from the pipeline's own embedder for self-consistency
        from repro.rag.pipeline import mean_pool_embedder
        embed = mean_pool_embedder(params, TINY)
        db = embed(doc_tokens)
        pipe = RAGPipeline(params, TINY, db, doc_tokens, d_start=8, k0=8)

        queries = doc_tokens[:4]      # queries == documents -> must retrieve self
        out = pipe.serve(queries, max_new_tokens=4)
        assert out["generated"].shape == (4, 4)
        assert out["retrieved"].shape[0] == 4
        np.testing.assert_array_equal(np.asarray(out["retrieved"][:, 0]),
                                      np.arange(4))

    def test_retrieval_stage_equals_core_search(self, corpus):
        rng = np.random.default_rng(0)
        params = LM.init_lm(jax.random.PRNGKey(0), TINY)
        doc_tokens = jnp.asarray(rng.integers(1, TINY.vocab, (32, 10)), jnp.int32)
        from repro.rag.pipeline import mean_pool_embedder
        embed = mean_pool_embedder(params, TINY)
        db = embed(doc_tokens)
        pipe = RAGPipeline(params, TINY, db, doc_tokens, d_start=8, k0=32)
        q_tokens = doc_tokens[:3]
        _, idx = pipe.retrieve(q_tokens)
        _, brute = truncated_search(embed(q_tokens), db, dim=db.shape[1], k=1)
        np.testing.assert_array_equal(np.asarray(idx[:, 0]),
                                      np.asarray(brute[:, 0]))

"""Core search correctness: truncated vs numpy oracle, progressive
invariants from the paper's §V analysis, PCA, IVF."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    build_index,
    fit_pca,
    fit_pca_power,
    ivf_progressive_search,
    ivf_search,
    build_ivf,
    make_schedule,
    pca_transform,
    progressive_search,
    progressive_search_pooled,
    rescore_candidates,
    stage_dims,
    top1_accuracy,
    truncated_search,
)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    N, D, Q = 3000, 256, 64
    scales = (1 + np.arange(D)) ** -0.3
    db = (rng.standard_normal((N, D)) * scales).astype(np.float32)
    gt = rng.choice(N, Q, replace=False)
    q = db[gt] + 0.4 * scales * rng.standard_normal((Q, D)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(db), jnp.asarray(gt)


def numpy_knn(q, db, dim, k):
    d2 = ((q[:, None, :dim] - db[None, :, :dim]) ** 2).sum(-1)
    return np.argsort(d2, axis=1, kind="stable")[:, :k]


class TestTruncated:
    def test_matches_numpy_oracle(self, corpus):
        q, db, gt = corpus
        for dim in (16, 64, 256):
            _, idx = truncated_search(q, db, dim=dim, k=5, block_n=512)
            ref = numpy_knn(np.asarray(q), np.asarray(db), dim, 5)
            assert (np.asarray(idx) == ref).mean() > 0.99  # fp tie tolerance

    def test_block_size_invariance(self, corpus):
        q, db, _ = corpus
        s1, i1 = truncated_search(q, db, dim=128, k=3, block_n=256)
        s2, i2 = truncated_search(q, db, dim=128, k=3, block_n=3000)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)

    def test_uneven_blocks_padding(self, corpus):
        q, db, _ = corpus
        s1, i1 = truncated_search(q, db, dim=64, k=2, block_n=999)
        s2, i2 = truncated_search(q, db, dim=64, k=2, block_n=3000)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_prefix_norms_equal_fresh_norms(self, corpus):
        q, db, _ = corpus
        sched = make_schedule(32, 256, 8)
        idx = build_index(db, stage_dims(sched))
        col = list(stage_dims(sched)).index(64)
        s1, i1 = truncated_search(q, db, dim=64, k=4,
                                  db_sq_at_dim=idx["sq_prefix"][:, col])
        s2, i2 = truncated_search(q, db, dim=64, k=4)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_cosine_metric(self, corpus):
        q, db, gt = corpus
        s, i = truncated_search(q, db, dim=256, k=1, metric="cosine")
        qn = np.asarray(q) / np.linalg.norm(q, axis=1, keepdims=True)
        dn = np.asarray(db) / np.linalg.norm(db, axis=1, keepdims=True)
        ref = (qn @ dn.T).argmax(1)
        assert (np.asarray(i[:, 0]) == ref).mean() > 0.99


class TestProgressive:
    def test_equals_truncated_at_dmax_with_large_k(self, corpus):
        """With k0 = N the candidate set never loses the true neighbour, so
        progressive == truncated at d_max exactly."""
        q, db, _ = corpus
        sched = make_schedule(32, 256, 512)
        _, pc = progressive_search(q, db, sched, block_n=512)
        _, tc = truncated_search(q, db, dim=256, k=1, block_n=512)
        assert (np.asarray(pc[:, 0]) == np.asarray(tc[:, 0])).mean() > 0.98

    def test_accuracy_bounded_by_endpoints(self, corpus):
        """Paper §V: progressive accuracy lies within [acc(Ds), acc(Dm)]."""
        q, db, gt = corpus
        _, lo = truncated_search(q, db, dim=32, k=1)
        _, hi = truncated_search(q, db, dim=256, k=1)
        acc_lo = float(top1_accuracy(lo, gt))
        acc_hi = float(top1_accuracy(hi, gt))
        for k0 in (4, 16, 64):
            sched = make_schedule(32, 256, k0)
            _, pc = progressive_search(q, db, sched)
            acc = float(top1_accuracy(pc, gt))
            assert acc_lo - 0.05 <= acc <= acc_hi + 1e-9

    def test_monotone_in_k0(self, corpus):
        q, db, gt = corpus
        accs = []
        for k0 in (2, 8, 32, 128):
            sched = make_schedule(16, 256, k0)
            _, pc = progressive_search(q, db, sched)
            accs.append(float(top1_accuracy(pc, gt)))
        assert all(a <= b + 0.03 for a, b in zip(accs, accs[1:]))

    def test_pooled_geq_perquery(self, corpus):
        """The paper's pooled variant sees a superset of each query's own
        candidates, so its accuracy >= the per-query variant's."""
        q, db, gt = corpus
        sched = make_schedule(16, 256, 8)
        _, pq = progressive_search(q, db, sched)
        _, pp = progressive_search_pooled(q, db, sched)
        assert float(top1_accuracy(pp, gt)) >= float(top1_accuracy(pq, gt)) - 1e-9

    def test_index_prefix_norms_give_same_result(self, corpus):
        q, db, _ = corpus
        sched = make_schedule(32, 256, 16)
        idx = build_index(db, stage_dims(sched))
        _, c1 = progressive_search(q, db, sched,
                                   sq_prefix=idx["sq_prefix"],
                                   index_dims=stage_dims(sched))
        _, c2 = progressive_search(q, db, sched)
        assert (np.asarray(c1) == np.asarray(c2)).mean() > 0.98


class TestRescore:
    def test_rescore_padding_masked(self, corpus):
        q, db, _ = corpus
        cand = jnp.tile(jnp.asarray([5, 17, -1, 42], jnp.int32), (q.shape[0], 1))
        s, i = rescore_candidates(q, db, cand, dim=128, k=3)
        assert not (np.asarray(i) == -1).any()
        assert np.isfinite(np.asarray(s)).all()

    def test_rescore_is_exact_on_candidates(self, corpus):
        q, db, _ = corpus
        rng = np.random.default_rng(1)
        cand = jnp.asarray(rng.choice(db.shape[0], (q.shape[0], 10)), jnp.int32)
        s, i = rescore_candidates(q, db, cand, dim=256, k=1)
        d2 = ((np.asarray(q)[:, None] - np.asarray(db)[np.asarray(cand)]) ** 2).sum(-1)
        best = np.asarray(cand)[np.arange(q.shape[0]), d2.argmin(1)]
        assert (np.asarray(i[:, 0]) == best).mean() > 0.99


class TestPCA:
    def test_orthonormal_components(self, corpus):
        _, db, _ = corpus
        st = fit_pca(db, 32)
        eye = np.asarray(st.components.T @ st.components)
        np.testing.assert_allclose(eye, np.eye(32), atol=1e-4)

    def test_power_iteration_matches_exact_subspace(self, corpus):
        _, db, _ = corpus
        exact = fit_pca(db, 8)
        power = fit_pca_power(db, 8, n_iter=20)
        # same subspace: projection of power components onto exact basis ~ I
        proj = np.asarray(exact.components.T @ power.components)
        s = np.linalg.svd(proj, compute_uv=False)
        assert s.min() > 0.97

    def test_transform_centers(self, corpus):
        _, db, _ = corpus
        st = fit_pca(db, 16)
        z = pca_transform(st, db)
        np.testing.assert_allclose(np.asarray(z.mean(0)), 0, atol=1e-3)


class TestQuantizedIndex:
    def test_int8_stage0_preserves_accuracy(self, corpus):
        """Precision-progressive search: int8 stage-0 block + exact rescore
        loses <2pts top-1 vs full-precision search (beyond-paper)."""
        from repro.core.quant import (build_quantized_index,
                                      quantized_progressive_search)
        q, db, gt = corpus
        from repro.core import make_schedule
        sched = make_schedule(128, 256, 64)
        idx = build_quantized_index(db, sched)
        _, i8 = quantized_progressive_search(q, idx, sched)
        _, f32 = truncated_search(q, db, dim=256, k=1)
        acc8 = float(top1_accuracy(i8, gt))
        accf = float(top1_accuracy(f32, gt))
        assert acc8 > accf - 0.02, (acc8, accf)

    def test_quantization_roundtrip_error_bounded(self, corpus):
        from repro.core.quant import quantize_per_dim
        _, db, _ = corpus
        qv, scale = quantize_per_dim(db)
        deq = qv.astype(np.float32) * np.asarray(scale)
        err = np.abs(deq - np.asarray(db))
        assert (err <= np.asarray(scale)[None, :] * 0.5 + 1e-6).all()


class TestIVF:
    def test_ivf_high_probe_equals_exact(self, corpus):
        q, db, gt = corpus
        ivf = build_ivf(db, 16, n_iter=5)
        _, i = ivf_search(q, db, ivf, n_probe=16, k=1)   # all lists probed
        _, t = truncated_search(q, db, dim=256, k=1)
        assert (np.asarray(i[:, 0]) == np.asarray(t[:, 0])).mean() > 0.98

    def test_ivf_progressive_recall(self, corpus):
        q, db, gt = corpus
        ivf = build_ivf(db, 16, n_iter=5)
        _, i = ivf_progressive_search(q, db, ivf, n_probe=8, k=1,
                                      d_probe=64, d_final=256)
        assert float(top1_accuracy(i, gt)) > 0.7

"""Property-based (hypothesis) tests on system invariants."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (
    make_schedule,
    progressive_search,
    truncated_search,
    rescore_candidates,
)
from repro.engine.batching import BucketPolicy, DeadlineBatcher, pad_batch
from repro.kernels import ref as kref
from repro.layers.common import softmax_xent


F32 = st.floats(-10, 10, width=32, allow_nan=False, allow_infinity=False)

# random bucket ladders: ascending unique positive sizes
LADDERS = st.lists(
    st.integers(1, 64), min_size=1, max_size=6, unique=True
).map(lambda xs: tuple(sorted(xs)))


@given(ladder=LADDERS, n=st.integers(1, 200))
@settings(max_examples=100, deadline=None)
def test_bucket_choice_is_minimal_in_ladder(ladder, n):
    """The chosen bucket covers the batch (when any bucket can) and is the
    *smallest* ladder element that does — no over-padding."""
    p = BucketPolicy(ladder)
    b = p.bucket_for(n)
    assert b in ladder
    if n <= p.max_size:
        assert b >= n                            # bucket >= batch size
        smaller = [s for s in ladder if s < b]
        assert all(s < n for s in smaller)       # minimal in the ladder
    else:
        assert b == p.max_size                   # oversized: caller splits


@given(ladder=LADDERS, n=st.integers(1, 200), extra=st.integers(1, 128))
@settings(max_examples=100, deadline=None)
def test_bucket_choice_stable_under_irrelevant_ladder_edits(ladder, n, extra):
    """The choice depends only on the relevant ladder slice: adding a
    strictly larger bucket, or dropping buckets too small to cover the
    batch, never perturbs it.  (Permutations of the *sizes* themselves are
    rejected by construction — BucketPolicy requires an ascending ladder —
    so irrelevant-edit invariance is the meaningful stability property.)"""
    p = BucketPolicy(ladder)
    b = p.bucket_for(n)
    if n <= p.max_size:
        # a new bucket above the chosen one can't become the minimal cover
        bigger = b + extra
        p_plus = BucketPolicy(tuple(sorted(set(ladder) | {bigger})))
        assert p_plus.bucket_for(n) == b
    # buckets below min(n, max) were never candidates; dropping them is a
    # no-op (for oversized n this leaves exactly the top bucket)
    kept = tuple(s for s in ladder if s >= min(n, p.max_size))
    assert BucketPolicy(kept).bucket_for(n) == b


@given(ladder=LADDERS, n=st.integers(0, 300))
@settings(max_examples=100, deadline=None)
def test_plan_covers_batch_with_bounded_padding(ladder, n):
    p = BucketPolicy(ladder)
    plan = p.plan(n)
    assert all(b in ladder for b in plan)
    assert sum(plan) >= n                        # every request gets a slot
    if n:
        assert sum(plan) - n < p.max_size        # padding strictly bounded
        assert all(b == p.max_size for b in plan[:-1])   # full buckets first
    else:
        assert plan == []


@given(
    data=st.data(),
    b=st.integers(1, 12),
    d=st.sampled_from([3, 8]),
    extra=st.integers(0, 9),
)
@settings(max_examples=50, deadline=None)
def test_pad_batch_preserves_prefix_and_zero_fills(data, b, d, extra):
    q = data.draw(hnp.arrays(np.float32, (b, d), elements=F32))
    out = pad_batch(q, b + extra)
    assert out.shape == (b + extra, d)
    np.testing.assert_array_equal(out[:b], q)    # real queries untouched
    assert (out[b:] == 0).all()                  # padding is zero queries


@given(
    ladder=LADDERS,
    n=st.integers(0, 200),
    wait=st.floats(0, 10, allow_nan=False),
    oldest=st.floats(0, 1e6, allow_nan=False),
    dt=st.floats(0, 20, allow_nan=False),
)
@settings(max_examples=100, deadline=None)
def test_deadline_batcher_decisions_are_sound(ladder, n, wait, oldest, dt):
    """For every queue state and clock reading: flushes never exceed the top
    bucket or the queue depth, waits are non-negative and never overshoot
    the deadline, and a full bucket always flushes."""
    b = DeadlineBatcher(BucketPolicy(ladder), max_wait_s=wait)
    now = oldest + dt
    deadline = oldest + wait      # same float expression the policy computes
    d = b.decide(n, oldest, now)
    if n == 0:
        assert d.action == "idle"
    elif n >= b.policy.max_size:
        assert (d.action, d.n, d.reason) == ("flush", b.policy.max_size,
                                             "full")
    elif now >= deadline:
        assert (d.action, d.n, d.reason) == ("flush", n, "deadline")
    else:
        assert d.action == "wait"
        # float slack: deadline/now each round once, so the remaining wait
        # can exceed max_wait_s by a couple of ulps at large clock values
        assert 0 < d.wait_s <= wait + 4 * math.ulp(deadline)
        # the clock reaching the deadline itself always flushes
        later = b.decide(n, oldest, deadline)
        assert later.action == "flush"


@given(
    data=st.data(),
    n=st.integers(8, 60),
    d=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 6),
)
@settings(max_examples=30, deadline=None)
def test_truncated_topk_is_sorted_and_valid(data, n, d, k):
    db = data.draw(hnp.arrays(np.float32, (n, d), elements=F32))
    q = data.draw(hnp.arrays(np.float32, (3, d), elements=F32))
    s, i = truncated_search(jnp.asarray(q), jnp.asarray(db), dim=d,
                            k=min(k, n), block_n=16)
    s, i = np.asarray(s), np.asarray(i)
    assert (np.diff(s, axis=1) >= -1e-5).all()          # ascending scores
    assert ((i >= 0) & (i < n)).all()                    # valid indices
    for row in i:                                        # no duplicates
        assert len(set(row.tolist())) == len(row)


@given(
    seed=st.integers(0, 2**31 - 1),
    d_start=st.sampled_from([4, 8]),
    mult=st.integers(1, 3),
    k0=st.sampled_from([2, 4, 16]),
)
@settings(max_examples=25, deadline=None)
def test_progressive_candidates_subset_of_db(seed, d_start, mult, k0):
    rng = np.random.default_rng(seed)
    d_max = d_start * 2**mult
    n = 64
    db = rng.normal(size=(n, d_max)).astype(np.float32)
    q = rng.normal(size=(5, d_max)).astype(np.float32)
    sched = make_schedule(d_start, d_max, k0)
    s, c = progressive_search(jnp.asarray(q), jnp.asarray(db), sched,
                              block_n=32)
    c = np.asarray(c)
    assert ((c >= 0) & (c < n)).all()
    # final score equals true distance-ranked score of that candidate
    s = np.asarray(s)
    sq = (db[c[:, 0]] ** 2).sum(-1)
    ip = np.einsum("qd,qd->q", q, db[c[:, 0]])
    np.testing.assert_allclose(s[:, 0], sq - 2 * ip, rtol=2e-3, atol=2e-3)


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(10, 50),
    c=st.integers(2, 10),
)
@settings(max_examples=25, deadline=None)
def test_rescore_never_invents_candidates(seed, n, c):
    rng = np.random.default_rng(seed)
    db = rng.normal(size=(n, 16)).astype(np.float32)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    cand = rng.choice(n, size=(4, c)).astype(np.int32)
    k = min(3, c)
    _, out = rescore_candidates(jnp.asarray(q), jnp.asarray(db),
                                jnp.asarray(cand), dim=16, k=k)
    out = np.asarray(out)
    for row_out, row_in in zip(out, cand):
        assert set(row_out.tolist()) <= set(row_in.tolist())


@given(
    seed=st.integers(0, 2**31 - 1),
    v=st.integers(5, 50),
    b=st.integers(1, 8),
    l=st.integers(1, 6),
)
@settings(max_examples=25, deadline=None)
def test_embedding_bag_ref_linearity(seed, v, b, l):
    """bag(t1 + t2) == bag(t1) + bag(t2): the reduce is linear in the table."""
    rng = np.random.default_rng(seed)
    t1 = rng.normal(size=(v, 8)).astype(np.float32)
    t2 = rng.normal(size=(v, 8)).astype(np.float32)
    idx = rng.integers(0, v, (b, l)).astype(np.int32)
    a = kref.embedding_bag_ref(jnp.asarray(t1 + t2), jnp.asarray(idx))
    bsum = (kref.embedding_bag_ref(jnp.asarray(t1), jnp.asarray(idx))
            + kref.embedding_bag_ref(jnp.asarray(t2), jnp.asarray(idx)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(bsum),
                               rtol=1e-4, atol=1e-4)


@given(
    data=st.data(),
    n=st.integers(2, 40),
    d=st.sampled_from([4, 8]),
)
@settings(max_examples=30, deadline=None)
def test_quantize_per_dim_round_trip_error_bounded(data, n, d):
    """int8 round trip: |x - q*scale| <= scale/2 per element (symmetric
    grid, ties-to-even rounding), and codes use the full int8 range."""
    from repro.core.quant import quantize_per_dim
    x = data.draw(hnp.arrays(np.float32, (n, d), elements=F32))
    q, scale = quantize_per_dim(jnp.asarray(x))
    q, scale = np.asarray(q, np.float32), np.asarray(scale)
    assert (np.abs(q) <= 127).all()
    deq = q * scale
    # rounding to the grid loses at most half a step per element
    assert (np.abs(x - deq) <= scale[None, :] / 2 + 1e-6).all()


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(8, 60),
    m=st.sampled_from([1, 2, 4]),
    n_codes=st.sampled_from([4, 16]),
)
@settings(max_examples=25, deadline=None)
def test_pq_encode_is_within_codebook_quantization_error(seed, n, m,
                                                         n_codes):
    """PQ round trip: encode picks the per-subspace nearest centroid, so
    reconstruction error is the codebook quantization error — no other
    code assignment reconstructs any row better."""
    from repro.core.pq import pq_decode, pq_encode, train_pq
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    cb = train_pq(x, m=m, n_codes=n_codes, n_iter=3,
                  key=jax.random.PRNGKey(seed))
    codes = pq_encode(x, cb)
    best = np.sum(
        (np.asarray(pq_decode(codes, cb)) - np.asarray(x)) ** 2, axis=1)
    other = jnp.asarray(
        rng.integers(0, n_codes, np.asarray(codes).shape).astype(np.uint8))
    err = np.sum(
        (np.asarray(pq_decode(other, cb)) - np.asarray(x)) ** 2, axis=1)
    assert (best <= err + 1e-4).all()


@given(
    seed=st.integers(0, 2**31 - 1),
    nq=st.integers(1, 6),
    m=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=25, deadline=None)
def test_pq_adc_rank_equivalent_to_decoded_l2(seed, nq, m):
    """ADC identity: summing a row's M LUT entries equals the
    rank-equivalent L2 score of the query vs that row's reconstruction —
    so ADC ranking == dequantized-L2 ranking exactly."""
    from repro.core.pq import pq_adc_scores, pq_decode, pq_encode, pq_lut, \
        train_pq
    from repro.core.truncated import l2_scores
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(nq, 8)).astype(np.float32))
    cb = train_pq(x, m=m, n_codes=8, n_iter=3, key=jax.random.PRNGKey(seed))
    codes = pq_encode(x, cb)
    adc = np.asarray(pq_adc_scores(pq_lut(q, cb), codes))
    exact = np.asarray(l2_scores(q, pq_decode(codes, cb)))
    np.testing.assert_allclose(adc, exact, rtol=1e-4, atol=1e-3)
    # rank equivalence wherever the decoded scores are not near-tied
    # (bit-tied rows — duplicate codes — are tied in both scorings)
    order = np.argsort(exact, axis=1, kind="stable")
    sorted_exact = np.take_along_axis(exact, order, axis=1)
    sorted_adc = np.take_along_axis(adc, order, axis=1)
    gap_ok = np.diff(sorted_exact, axis=1) > 1e-3
    assert (np.diff(sorted_adc, axis=1)[gap_ok] > 0).all()


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_softmax_xent_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(4, 7, 11)).astype(np.float32)
    labels = rng.integers(0, 11, (4, 7)).astype(np.int32)
    labels[0, 0] = -100   # ignored
    loss, n = softmax_xent(jnp.asarray(logits), jnp.asarray(labels))
    # numpy reference
    e = np.exp(logits - logits.max(-1, keepdims=True))
    p = e / e.sum(-1, keepdims=True)
    valid = labels >= 0
    nll = -np.log(p.reshape(-1, 11)[np.arange(labels.size),
                                    np.maximum(labels, 0).reshape(-1)])
    expected = nll.reshape(labels.shape)[valid].mean()
    np.testing.assert_allclose(float(loss), expected, rtol=1e-4)
    assert int(n) == valid.sum()


@given(
    seed=st.integers(0, 2**31 - 1),
    causal=st.booleans(),
)
@settings(max_examples=15, deadline=None)
def test_attention_rows_are_convex_combinations(seed, causal):
    """Attention output rows lie in the convex hull of V rows: for V >= 0,
    outputs are >= 0 and <= max(V)."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(1, 2, 8, 4)).astype(np.float32)
    k = rng.normal(size=(1, 2, 8, 4)).astype(np.float32)
    v = rng.uniform(0, 1, size=(1, 2, 8, 4)).astype(np.float32)
    o = kref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), causal=causal)
    o = np.asarray(o)
    assert (o >= -1e-5).all() and (o <= 1 + 1e-5).all()


# -- tenant isolation under random mutation ---------------------------------
# One engine shared across examples (module fixture): hypothesis drives
# random interleavings of tenant-tagged adds, deletes, forced rebuilds
# (compaction remaps), and searches against it.  The invariant is checked
# against the live store on every search, so accumulated state across
# examples only makes the workload more adversarial, never stale.

@pytest.fixture(scope="module")
def iso_engine():
    from repro.engine import RetrievalEngine

    eng = RetrievalEngine(16, d_start=8, k0=8, final_k=4, buckets=(2,),
                          capacity=64, block_n=32, compact_dead_frac=0.5)
    eng.add_docs(np.random.default_rng(0).normal(
        size=(20, 16)).astype(np.float32))        # tenantless pool
    return eng


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_tenant_isolation_under_random_mutation(iso_engine, data):
    """A search constrained to tenant T returns only rows whose live owner
    is T (and whose metadata matches the filter), no matter what sequence
    of adds/deletes/compactions preceded it."""
    eng = iso_engine
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31 - 1)))
    for _ in range(data.draw(st.integers(1, 6))):
        op = data.draw(st.sampled_from(
            ("add", "add", "delete", "rebuild", "search", "search")))
        if op == "add":
            tenant = data.draw(st.sampled_from((None, "A", "B")))
            n = data.draw(st.integers(1, 3))
            eng.add_docs(
                rng.normal(size=(n, 16)).astype(np.float32),
                tenant=tenant,
                metadata=[{"g": int(rng.integers(3))} for _ in range(n)])
        elif op == "delete":
            live = [i for i in range(eng.store.size) if eng.store.is_live(i)]
            if len(live) > 8:                     # keep the corpus non-empty
                eng.delete_docs(rng.choice(live, 2, replace=False))
        elif op == "rebuild":
            eng.maybe_rebuild(force=True)         # compacts past dead-frac
        else:
            tenant = data.draw(st.sampled_from(("A", "B", "ghost")))
            filt = (None if data.draw(st.booleans())
                    else {"g": {"$eq": data.draw(st.integers(0, 2))}})
            _, idx = eng.search(rng.normal(size=(2, 16)).astype(np.float32),
                                tenant=tenant, filter=filt)
            for i in idx.ravel():
                if i < 0:
                    continue
                assert eng.store.tenant_of(int(i)) == tenant
                if filt is not None:
                    got = eng.store.metadata_of(int(i)).get("g")
                    assert got == filt["g"]["$eq"]

"""Index-backend subsystem: protocol registry, recall vs the flat baseline,
add/delete-then-rebuild correctness, tail injection, compaction remaps, and
the background-build lifecycle."""

import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import truncated_search, overlap_at_k
from repro.core.ivf import balanced_assign
from repro.engine import DocStore, RetrievalEngine
from repro.index_backends import (
    FlatProgressiveBackend,
    IndexBackend,
    StoreStats,
    backend_names,
    make_backend,
)

RNG = np.random.default_rng(11)
D = 32
REGISTERED = ("flat", "ivf", "quantized")
# "ivf_kernel" is the ivf backend with the fused Pallas stage-0 scan forced
# (interpret mode on CPU), "ivf_pq" composes it with PQ member slabs, and
# "quantized_pq" is the quantized backend's ADC codec — every variant must
# pass the identical engine contract
BACKENDS = REGISTERED + ("ivf_kernel", "ivf_pq", "quantized_pq")


def opts_for(backend, **extra):
    base = {
        "flat": {},
        # small corpora: force real clustering instead of the flat fallback
        "ivf": dict(n_lists=12, n_probe=6, min_index_rows=32,
                    min_rebuild_rows=16),
        "ivf_kernel": dict(n_lists=12, n_probe=6, min_index_rows=32,
                           min_rebuild_rows=16, use_kernel=True,
                           kernel_block_m=16),
        "ivf_pq": dict(n_lists=12, n_probe=6, min_index_rows=32,
                       min_rebuild_rows=16, use_kernel=True,
                       kernel_block_m=16, stage0_dtype="pq"),
        "quantized": dict(min_rebuild_rows=16),
        "quantized_pq": dict(min_rebuild_rows=16, codec="pq"),
    }[backend]
    return {**base, **extra} or None


def engine_backend(backend):
    if backend.startswith("ivf"):
        return "ivf"
    if backend.startswith("quantized"):
        return "quantized"
    return backend


def make_engine(backend, n_docs=200, seed=7, **kw):
    opts = kw.pop("backend_opts", opts_for(backend))
    kw.setdefault("d_start", 8)
    kw.setdefault("k0", 16)
    kw.setdefault("buckets", (4,))
    kw.setdefault("capacity", 64)
    kw.setdefault("block_n", 64)
    eng = RetrievalEngine(D, backend=engine_backend(backend),
                          backend_opts=opts, **kw)
    db = np.random.default_rng(seed).normal(size=(n_docs, D)).astype(np.float32)
    eng.add_docs(db)
    return eng, db


class TestRegistry:
    def test_names(self):
        assert set(REGISTERED) <= set(backend_names())

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown index backend"):
            RetrievalEngine(D, backend="hnsw")

    def test_instance_passthrough_and_opts_conflict(self):
        from repro.core import make_schedule
        sched = make_schedule(8, D, 16)
        be = FlatProgressiveBackend(sched)
        assert make_backend(be, sched=sched) is be
        with pytest.raises(ValueError):
            make_backend(be, sched=sched, n_probe=4)

    def test_bad_rebuild_mode_rejected(self):
        with pytest.raises(ValueError, match="rebuild_mode"):
            RetrievalEngine(D, rebuild_mode="eager")

    def test_quantized_rejects_cosine(self):
        with pytest.raises(ValueError, match="l2"):
            RetrievalEngine(D, backend="quantized", metric="cosine")


@pytest.mark.parametrize("backend", BACKENDS)
class TestBackendEngineSuite:
    """Every backend must pass the same search/add/delete/rebuild contract."""

    def test_exact_query_self_retrieval(self, backend):
        eng, db = make_engine(backend)
        _, idx = eng.search(db[:8])
        np.testing.assert_array_equal(idx[:, 0], np.arange(8))

    def test_deleted_doc_never_returned(self, backend):
        eng, db = make_engine(backend)
        _, before = eng.search(db[17:18])
        assert before[0, 0] == 17
        eng.delete_docs([17])
        _, after = eng.search(db[17:18])
        assert 17 not in after
        rid = eng.submit(db[17])
        eng.run_until_idle()
        assert 17 not in eng.poll(rid).doc_ids

    def test_added_doc_visible_without_rebuild(self, backend):
        # tail injection: a doc appended after the index build must be
        # retrievable before any rebuild happens
        eng, db = make_engine(backend)
        eng.search(db[:1])                      # force the initial build
        n_rebuilds = eng.stats.n_rebuilds
        new = RNG.normal(size=(1, D)).astype(np.float32) * 5.0
        [nid] = eng.add_docs(new)
        _, idx = eng.search(new)
        assert idx[0, 0] == nid
        assert eng.stats.n_rebuilds == n_rebuilds

    def test_delete_survives_rebuild(self, backend):
        eng, db = make_engine(backend)
        eng.delete_docs([5])
        _, idx = eng.search(db[5:6])
        assert 5 not in idx
        assert eng.maybe_rebuild(force=True)
        _, idx = eng.search(db[5:6])
        assert 5 not in idx
        assert eng.index_state.built_active == len(db) - 1

    def test_churn_triggers_natural_rebuild(self, backend):
        eng, db = make_engine(backend)
        eng.search(db[:1])
        n_rebuilds = eng.stats.n_rebuilds
        # exceed min_rebuild_rows (flat never rebuilds by design)
        extra = RNG.normal(size=(80, D)).astype(np.float32)
        ids = eng.add_docs(extra)
        _, idx = eng.search(extra[:4])
        np.testing.assert_array_equal(idx[:, 0], ids[:4])
        if backend == "flat":
            assert eng.stats.n_rebuilds == n_rebuilds
        else:
            assert eng.stats.n_rebuilds > n_rebuilds

    def test_fully_deleted_corpus_returns_sentinel(self, backend):
        eng, db = make_engine(backend, n_docs=40)
        eng.delete_docs(np.arange(40))
        scores, idx = eng.search(db[:2])
        assert (idx == -1).all()
        assert np.isinf(scores).all()

    def test_tail_overflow_forces_rebuild_even_when_off(self, backend):
        if backend == "flat":
            pytest.skip("flat covers every row; no tail window")
        # append_spare=0 / encode_appends=False turn incremental absorption
        # off (where supported), so appends land in the tail window and the
        # hard bound must fire
        opts = opts_for(backend, min_rebuild_rows=4, rebuild_frac=0.01)
        if "ivf" in backend:
            opts["append_spare"] = 0
        if backend.startswith("quantized"):
            opts["encode_appends"] = False
        eng, db = make_engine(backend, backend_opts=opts,
                              rebuild_mode="off")
        eng.search(db[:1])
        n_rebuilds = eng.stats.n_rebuilds
        extra = RNG.normal(size=(12, D)).astype(np.float32)  # > tail_cap=4
        ids = eng.add_docs(extra)
        _, idx = eng.search(extra)
        np.testing.assert_array_equal(idx[:, 0], ids)
        assert eng.stats.n_rebuilds > n_rebuilds


@pytest.mark.parametrize(
    "backend", ("ivf", "ivf_kernel", "ivf_pq", "quantized", "quantized_pq"))
class TestRecall:
    def test_recall_vs_flat_on_clustered_corpus(self, backend):
        from repro.rag import make_clustered_corpus
        c = make_clustered_corpus(n_docs=1536, dim=64, n_queries=32,
                                  n_clusters=24, seed=5)
        _, exact = truncated_search(
            jnp.asarray(c.queries), jnp.asarray(c.db), dim=64, k=10,
            block_n=1536)

        def run(be, opts):
            eng = RetrievalEngine(
                64, d_start=16, k0=64, final_k=10, buckets=(32,),
                capacity=1536, block_n=1536, backend=be, backend_opts=opts)
            eng.add_docs(c.db)
            _, ids = eng.search(c.queries)
            return float(overlap_at_k(jnp.asarray(ids), exact, 10))

        flat = run("flat", None)
        opts = None
        if "ivf" in backend:
            opts = dict(n_lists=24, n_probe=8, min_index_rows=32)
            if backend in ("ivf_kernel", "ivf_pq"):
                opts["use_kernel"] = True
            if backend == "ivf_pq":
                opts["stage0_dtype"] = "pq"
        elif backend == "quantized_pq":
            opts = dict(codec="pq")
        approx = run(engine_backend(backend), opts)
        assert flat >= 0.9                       # schedule is wide enough
        # approximate backends stay within 10 points of the exact baseline
        assert approx >= flat - 0.10


class TestCompaction:
    def test_store_compact_unit(self):
        dims = (8, 16, 32)
        store = DocStore(D, dims, capacity=4)
        rows = RNG.normal(size=(10, D)).astype(np.float32)
        store.add(rows)
        store.delete([0, 3, 4, 9])
        id_map = store.compact()
        assert store.size == store.n_active == 6
        assert store.capacity == 8               # pow2 shrink from 16
        assert store.n_compactions == 1
        live_old = [1, 2, 5, 6, 7, 8]
        np.testing.assert_array_equal(id_map[live_old], np.arange(6))
        assert (id_map[[0, 3, 4, 9]] == -1).all()
        np.testing.assert_allclose(
            np.asarray(store.db[:6]), rows[live_old], rtol=1e-6)
        # prefix norms must match a fresh build over the surviving rows
        from repro.core import build_index
        ref = build_index(jnp.asarray(rows[live_old]), dims)
        np.testing.assert_allclose(
            np.asarray(store.sq_prefix[:6]), np.asarray(ref["sq_prefix"]),
            rtol=1e-5, atol=1e-5)
        # lifetime counters keep their pre-compaction history
        assert store.total_added == 10 and store.total_deleted == 4

    def test_engine_compacts_and_remaps(self):
        eng, db = make_engine("flat", n_docs=100, compact_dead_frac=0.4)
        # an unpolled result that must be remapped across the compaction
        rid = eng.submit(db[60])
        eng.run_until_idle()
        eng.delete_docs(np.arange(50))           # 50% dead
        maps = []
        eng.on_remap.append(maps.append)
        _, idx = eng.search(db[60:61])
        assert eng.stats.n_compactions == 1 and len(maps) == 1
        assert eng.store.size == 50
        assert idx[0, 0] == 10                   # doc 60 slid down by 50
        res = eng.poll(rid)
        assert res.doc_ids[0] == 10              # unpolled result followed

    def test_no_compaction_below_threshold(self):
        eng, db = make_engine("flat", n_docs=100, compact_dead_frac=0.4)
        eng.delete_docs(np.arange(10))
        eng.search(db[50:51])
        assert eng.stats.n_compactions == 0

    def test_compaction_survives_raising_remap_callback(self):
        # a failing on_remap callback must not leave a pre-compaction index
        # state serving remapped buffers (silently wrong documents): the
        # engine rebuilds first, then the callback's error reaches the caller
        eng, db = make_engine("ivf", n_docs=120, compact_dead_frac=0.3)
        eng.search(db[:1])

        def boom(id_map):
            raise RuntimeError("callback failed")

        eng.on_remap.append(boom)
        eng.delete_docs(np.arange(0, 120, 2))
        with pytest.raises(RuntimeError, match="callback failed"):
            eng.search(db[1:2])
        eng.on_remap.remove(boom)
        assert eng.stats.n_compactions == 1
        _, idx = eng.search(db[1:7:2])           # odd (surviving) docs
        np.testing.assert_array_equal(idx[:, 0], [0, 1, 2])

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_post_compaction_search_correct(self, backend):
        eng, db = make_engine(backend, n_docs=120, compact_dead_frac=0.3)
        eng.search(db[:1])
        eng.delete_docs(np.arange(0, 120, 2))    # half the corpus
        _, idx = eng.search(db[1:7:2])           # odd (surviving) docs
        assert eng.stats.n_compactions == 1
        # old ids 1,3,5 -> compacted ids 0,1,2
        np.testing.assert_array_equal(idx[:, 0], [0, 1, 2])

    @staticmethod
    def _make_pipe(doc_tokens):
        import jax
        from repro.configs.base import LMConfig
        from repro.models import lm as LM
        from repro.rag import RAGPipeline
        from repro.rag.pipeline import mean_pool_embedder
        cfg = LMConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_head=16, d_ff=64, vocab=128,
                       param_dtype="float32", compute_dtype="float32",
                       remat=False)
        params = LM.init_lm(jax.random.PRNGKey(0), cfg)
        db = mean_pool_embedder(params, cfg)(jnp.asarray(doc_tokens))
        return RAGPipeline(params, cfg, db, doc_tokens, d_start=4, k0=4), db

    def test_pipeline_tokens_follow_compaction(self):
        toks = jnp.asarray(
            np.random.default_rng(0).integers(1, 128, (12, 5)), jnp.int32)
        pipe, _ = self._make_pipe(toks)
        pipe.delete_docs(list(range(8)))         # > default compact frac
        target = np.asarray(toks[10:11])
        _, idx = pipe.retrieve(jnp.asarray(target))
        assert pipe.engine.stats.n_compactions == 1
        # retrieved id indexes the REMAPPED token table, same text comes back
        np.testing.assert_array_equal(
            pipe.doc_tokens[idx[0, 0]], target[0])

    def test_compaction_never_writes_through_caller_tokens(self):
        # the constructor aliases a writable caller array; the remap must
        # copy-on-write instead of shuffling the caller's rows in place
        toks = np.random.default_rng(0).integers(
            1, 128, (12, 5)).astype(np.int32)
        before = toks.copy()
        pipe, _ = self._make_pipe(toks)
        pipe.delete_docs(list(range(8)))
        pipe.retrieve(jnp.asarray(toks[10:11]))
        assert pipe.engine.stats.n_compactions == 1
        np.testing.assert_array_equal(toks, before)

    def test_pipeline_rejects_backend_conflicting_with_engine(self):
        toks = jnp.asarray(
            np.random.default_rng(0).integers(1, 128, (6, 5)), jnp.int32)
        pipe, db = self._make_pipe(toks)
        from repro.rag import RAGPipeline
        eng = RetrievalEngine(db.shape[1], d_start=4, k0=4, capacity=8)
        with pytest.raises(ValueError, match="backend"):
            RAGPipeline(pipe.lm_params, pipe.cfg, db, toks, engine=eng,
                        backend="ivf")


class TestBackgroundRebuild:
    def _wait_rebuild(self, eng, n_before, timeout=30.0):
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < timeout:
            eng.maybe_rebuild()                  # adopt when ready
            if eng.stats.n_rebuilds > n_before:
                return True
            time.sleep(0.02)
        return False

    def test_background_build_adopts_state(self):
        # soft threshold <= rows added (16) <= tail window (32): the
        # rebuild is wanted but not correctness-forced -> background path
        opts = opts_for("ivf", min_rebuild_rows=8, rebuild_frac=0.05)
        eng, db = make_engine("ivf", backend_opts=opts,
                              rebuild_mode="background")
        eng.search(db[:1])
        n_before = eng.stats.n_rebuilds
        built_size_before = eng.index_state.built_size
        extra = RNG.normal(size=(16, D)).astype(np.float32)
        ids = eng.add_docs(extra)
        _, idx = eng.search(extra[:4])           # serves old state + tail
        np.testing.assert_array_equal(idx[:, 0], ids[:4])
        assert self._wait_rebuild(eng, n_before)
        assert eng.index_state.built_size > built_size_before
        _, idx = eng.search(extra[:4])           # new state agrees
        np.testing.assert_array_equal(idx[:, 0], ids[:4])


    def test_stale_background_build_never_reverts_newer_state(self):
        opts = opts_for("ivf", min_rebuild_rows=8, rebuild_frac=0.05)
        eng, db = make_engine("ivf", backend_opts=opts,
                              rebuild_mode="background")
        eng.search(db[:1])
        eng.add_docs(RNG.normal(size=(16, D)).astype(np.float32))
        eng.search(db[:1])                       # launches background build
        ids = eng.add_docs(RNG.normal(size=(4, D)).astype(np.float32))
        eng.maybe_rebuild(force=True)            # newer sync state lands
        forced = eng.index_state
        t0 = time.perf_counter()
        while not eng._bg.idle and time.perf_counter() - t0 < 30:
            eng.maybe_rebuild()                  # offers the stale build
            time.sleep(0.02)
        assert eng._bg.idle
        # the finished background build predates the forced one: rejected
        assert eng.index_state.generation >= forced.generation
        assert eng.index_state.built_size >= forced.built_size
        _, idx = eng.search(db[:2])              # still serving correctly
        np.testing.assert_array_equal(idx[:, 0], [0, 1])
        assert eng.store.is_live(int(ids[0]))


class TestIncrementalAbsorb:
    """Incremental IVF maintenance: appended rows join their nearest
    centroid's spare list slots between rebuilds; only rows whose list is
    full ride the tail window, and the rebuild bounds count only those."""

    def _build(self, n_docs=96, **opts):
        from repro.core import make_schedule
        sched = make_schedule(8, D, 16)
        base = dict(n_lists=8, n_probe=8, min_index_rows=16,
                    balance_factor=1.0, append_spare=4, tail_window=16,
                    min_rebuild_rows=4, rebuild_frac=10.0)  # churn disabled
        base.update(opts)
        be = make_backend("ivf", sched=sched, **base)
        store = DocStore(D, (8, 16, 32), capacity=128)
        store.add(RNG.normal(size=(n_docs, D)).astype(np.float32))
        state = be.build(store.db, store.valid,
                         sq_prefix=store.sq_prefix, stats=store.stats())
        return be, store, state

    def _absorb(self, be, store, state):
        be.absorb_appends(state, store.db, store.valid,
                          sq_prefix=store.sq_prefix, stats=store.stats())

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_appends_absorbed_into_lists(self, use_kernel):
        be, store, state = self._build(use_kernel=use_kernel,
                                       kernel_block_m=16)
        new = RNG.normal(size=(4, D)).astype(np.float32) * 3
        ids = store.add(new)
        self._absorb(be, store, state)
        assert state.data["absorb_upto"] == store.size
        assert len(state.data["tail_pending"]) == 0
        # reachable through the LISTS: the tail window is empty
        assert (be._tail_ids(state, store.size) == -1).all()
        _, idx = be.search(jnp.asarray(new), state, store.db, store.valid,
                           sq_prefix=store.sq_prefix, n_total=store.size,
                           k=1)
        np.testing.assert_array_equal(np.asarray(idx)[:, 0], ids)
        assert not be.must_rebuild(state, store.stats())
        assert not be.needs_rebuild(state, store.stats())

    def test_full_lists_overflow_to_tail_then_force_rebuild(self):
        be, store, state = self._build()
        # total list capacity is 8 lists x 16 slots = 128; 96 built rows
        # leave at most 32 free slots, so 60 appends must overflow
        store.add(RNG.normal(size=(60, D)).astype(np.float32))
        self._absorb(be, store, state)
        assert state.data["absorb_upto"] == store.size
        pend = state.data["tail_pending"]
        assert len(pend) >= 60 - 32
        # the overflow exceeds the tail window: the hard bound fires — an
        # engine would rebuild before the next dispatch
        assert be.must_rebuild(state, store.stats())

    def test_absorb_disabled_with_zero_spare(self):
        be, store, state = self._build(append_spare=0)
        store.add(RNG.normal(size=(4, D)).astype(np.float32))
        self._absorb(be, store, state)
        assert state.data["absorb_upto"] == 96      # untouched
        # appended rows still reachable — via the tail window
        tail = be._tail_ids(state, store.size)
        np.testing.assert_array_equal(tail[:4], np.arange(96, 100))

    def test_tombstoned_pending_rows_pruned(self):
        be, store, state = self._build()
        store.add(RNG.normal(size=(60, D)).astype(np.float32))
        self._absorb(be, store, state)
        pend = state.data["tail_pending"]
        assert len(pend) > 0
        store.delete(pend.tolist())
        self._absorb(be, store, state)              # no new rows; prunes
        # deleted pending rows no longer hold tail-window capacity
        assert len(state.data["tail_pending"]) == 0

    @pytest.mark.parametrize("backend", ("ivf", "ivf_kernel"))
    def test_engine_absorbs_appends_without_rebuild(self, backend):
        eng, db = make_engine(backend)
        eng.search(db[:1])                          # initial build
        n_rb = eng.stats.n_rebuilds
        new = RNG.normal(size=(8, D)).astype(np.float32) * 4
        ids = eng.add_docs(new)
        _, idx = eng.search(new)
        np.testing.assert_array_equal(idx[:, 0], ids)
        st = eng.index_state
        assert st.data["absorb_upto"] == eng.store.size
        assert len(st.data["tail_pending"]) == 0
        assert eng.stats.n_rebuilds == n_rb
        # a deleted absorbed row is unreturnable immediately
        eng.delete_docs([int(ids[0])])
        _, idx = eng.search(new[:1])
        assert int(ids[0]) not in idx


class TestStaleness:
    def test_needs_rebuild_thresholds(self):
        from repro.core import make_schedule
        sched = make_schedule(8, D, 16)
        be = make_backend("ivf", sched=sched, n_lists=4,
                          rebuild_frac=0.5, min_rebuild_rows=10,
                          min_index_rows=4)
        store = DocStore(D, (8, 16, 32), capacity=64)
        store.add(RNG.normal(size=(40, D)).astype(np.float32))
        state = be.build(store.db, store.valid, sq_prefix=store.sq_prefix,
                         stats=store.stats())
        assert not be.needs_rebuild(state, store.stats())
        store.delete(np.arange(5))               # churn 5 < 20
        assert not be.needs_rebuild(state, store.stats())
        store.add(RNG.normal(size=(15, D)).astype(np.float32))
        assert be.needs_rebuild(state, store.stats())  # churn 20 >= 20

    def test_stats_properties(self):
        st = StoreStats(size=10, n_active=6, capacity=16, generation=3,
                        total_added=10, total_deleted=4)
        assert st.n_dead == 4
        assert st.dead_frac == pytest.approx(0.4)
        assert StoreStats(0, 0, 1, 0, 0, 0).dead_frac == 0.0


class TestIndexCheckpoint:
    """Persist/restore built index state through `repro.checkpoint`:
    serving restarts skip the k-means / codebook builds."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_round_trip_identical_results(self, backend, tmp_path):
        eng, db = make_engine(backend)
        s1, i1 = eng.search(db[:8])
        eng.save_index(str(tmp_path))

        eng2, _ = make_engine(backend)              # same corpus, no build
        assert eng2.load_index(str(tmp_path))
        assert eng2.stats.n_rebuilds == 0           # the point of loading
        s2, i2 = eng2.search(db[:8])
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)
        # staleness restarts clean: nothing to rebuild right after load
        assert not eng2.backend.needs_rebuild(
            eng2.index_state, eng2.store.stats())

    @pytest.mark.parametrize("backend", ("ivf", "quantized_pq"))
    def test_loaded_state_serves_mutations(self, backend, tmp_path):
        eng, db = make_engine(backend)
        eng.search(db[:1])
        eng.save_index(str(tmp_path))
        eng2, _ = make_engine(backend)
        assert eng2.load_index(str(tmp_path))
        new = RNG.normal(size=(3, D)).astype(np.float32) * 5.0
        ids = eng2.add_docs(new)
        _, got = eng2.search(new)
        np.testing.assert_array_equal(got[:, 0], ids)
        eng2.delete_docs([7])
        _, after = eng2.search(db[7:8])
        assert 7 not in after

    def test_missing_checkpoint_returns_false(self, tmp_path):
        eng, _ = make_engine("flat")
        assert not eng.load_index(str(tmp_path / "nope"))

    def test_backend_kind_mismatch_raises(self, tmp_path):
        eng, db = make_engine("ivf")
        eng.search(db[:1])
        eng.save_index(str(tmp_path))
        eng2, _ = make_engine("quantized")
        with pytest.raises(ValueError, match="backend"):
            eng2.load_index(str(tmp_path))

    def test_codec_mismatch_raises(self, tmp_path):
        eng, db = make_engine("quantized_pq")
        eng.search(db[:1])
        eng.save_index(str(tmp_path))
        eng2, _ = make_engine("quantized")
        with pytest.raises(ValueError, match="codec"):
            eng2.load_index(str(tmp_path))

    def test_oversized_index_rejected(self, tmp_path):
        eng, db = make_engine("ivf")
        eng.search(db[:1])
        eng.save_index(str(tmp_path))
        eng2, _ = make_engine("ivf", n_docs=20)     # smaller corpus
        with pytest.raises(ValueError, match="re-add the corpus"):
            eng2.load_index(str(tmp_path))


class TestBalancedAssign:
    def test_respects_cap_and_preference(self):
        choices = np.array([[0, 1], [0, 1], [0, 1], [1, 0]])
        order = np.arange(4)
        assign = balanced_assign(choices, order, n_lists=2, cap=2)
        counts = np.bincount(assign, minlength=2)
        assert (counts <= 2).all() and counts.sum() == 4
        # first two (most confident) rows keep their first choice
        assert assign[0] == 0 and assign[1] == 0
        assert assign[3] == 1                    # its own first choice

    def test_overflow_rows_spill_to_free_lists(self):
        choices = np.zeros((6, 1), np.int64)     # everyone wants list 0
        assign = balanced_assign(choices, np.arange(6), n_lists=3, cap=2)
        assert (np.bincount(assign, minlength=3) == 2).all()

    def test_impossible_cap_raises(self):
        with pytest.raises(ValueError):
            balanced_assign(np.zeros((5, 1), np.int64), np.arange(5),
                            n_lists=2, cap=2)


class TestProtocolSubclass:
    def test_custom_backend_pluggable(self):
        # the protocol is the extension point: a trivial user backend that
        # delegates to flat must slot into the engine unchanged
        from repro.core import make_schedule

        class EchoBackend(FlatProgressiveBackend):
            name = "echo-test"

        sched = make_schedule(8, D, 16)
        eng = RetrievalEngine(D, d_start=8, k0=16, capacity=32,
                              buckets=(2,), block_n=32,
                              backend=EchoBackend(sched))
        db = RNG.normal(size=(20, D)).astype(np.float32)
        eng.add_docs(db)
        _, idx = eng.search(db[:2])
        np.testing.assert_array_equal(idx[:, 0], [0, 1])
        assert isinstance(eng.backend, IndexBackend)


class TestDriverCompactionInterleave:
    """Compaction/background rebuilds racing in-flight driver requests.

    The engine compacts at safe points *between* driver dispatches; every id
    a client polls must survive the remap protocol — an ``on_remap``
    subscriber applying the engine's id maps to previously-returned ids must
    always land on a valid row (or the -1 tombstone sentinel), never out of
    range.  Regression for the driver/rebuild safe-point composition.
    """

    @pytest.mark.slow
    @pytest.mark.parametrize("rebuild_mode", ("sync", "background"))
    def test_polled_ids_survive_remap_under_driver_traffic(self, rebuild_mode):
        import threading

        from repro.engine import EngineDriver

        eng = RetrievalEngine(
            D, d_start=8, k0=16, buckets=(1, 2, 4), capacity=1024,
            block_n=64, backend="flat", rebuild_mode=rebuild_mode,
            compact_dead_frac=0.2,
        )
        rng = np.random.default_rng(5)
        base = rng.normal(size=(120, D)).astype(np.float32)
        eng.add_docs(base)
        eng.warmup()

        # on_remap subscriber: replays every engine id map over all ids the
        # clients registered so far (same protocol RAGPipeline relies on)
        polled = []                       # mutated under eng.lock only
        last_remap_gen = [0]              # store generation of last remap
        def follow_remap(id_map):
            for ids in polled:
                live = ids >= 0
                assert ids[live].max(initial=-1) < id_map.shape[0]
                ids[live] = id_map[ids[live]]
            last_remap_gen[0] = eng.store.generation
        eng.on_remap.append(follow_remap)

        errors = []

        def client(seed):
            r = np.random.default_rng(seed)
            try:
                for _ in range(12):
                    res = driver.retrieve(base[r.integers(len(base))],
                                          timeout=30.0)
                    ids = np.array(res.doc_ids, np.int64)
                    with eng.lock:        # serialize vs compaction remaps
                        if res.store_generation < last_remap_gen[0]:
                            # a compaction landed between dispatch and this
                            # registration: the ids predate a map we never
                            # saw — exactly what store_generation exists to
                            # detect.  A real client would re-retrieve.
                            continue
                        assert (ids < eng.store.size).all()
                        polled.append(ids)
            except Exception as e:
                errors.append(e)

        with EngineDriver(eng, max_wait_ms=1.0) as driver:
            threads = [threading.Thread(target=client, args=(i,), daemon=True)
                       for i in range(4)]
            for t in threads:
                t.start()
            # deletes push dead_frac past compact_dead_frac repeatedly while
            # clients are in flight; adds keep the corpus from emptying
            for round_ in range(4):
                with eng.lock:
                    # snapshot + delete atomically: a driver-thread
                    # compaction between them would remap the snapshot's ids
                    # out from under the delete (the lock is reentrant)
                    live = [i for i in range(eng.store.size)
                            if eng.store.is_live(i)]
                    eng.delete_docs(live[:len(live) // 3])
                eng.add_docs(rng.normal(size=(20, D)).astype(np.float32))
            for t in threads:
                t.join(timeout=30.0)
                assert not t.is_alive(), "client thread hung"
        assert not errors, errors[:3]
        assert eng.stats.n_compactions >= 1, "no compaction ever triggered"
        assert polled, "every result raced a compaction — nothing verified"
        # after all remaps: every recorded id is -1 or an in-range row
        for ids in polled:
            live = ids[ids >= 0]
            assert (live < eng.store.size).all()


@pytest.mark.parametrize("backend", BACKENDS)
class TestTenantIsolation:
    """A search under tenant A never returns tenant B's (or the tenantless
    pool's) docs.  The constraint is one bitmask AND in the dispatch path —
    backend-independent by construction — so every variant must pass the
    identical contract, including across deletes and compaction remaps."""

    def test_search_scoped_to_own_tenant(self, backend):
        eng, db = make_engine(backend)            # 200 tenantless docs
        rng = np.random.default_rng(3)
        a = rng.normal(size=(40, D)).astype(np.float32)
        b = rng.normal(size=(40, D)).astype(np.float32)
        ids_a = set(eng.add_docs(a, tenant="A").tolist())
        ids_b = set(eng.add_docs(b, tenant="B").tolist())
        # querying with B's own vectors under tenant A is the adversarial
        # case: the nearest rows by geometry all belong to B
        _, idx = eng.search(b[:8], tenant="A")
        hit = set(int(i) for i in idx.ravel() if i >= 0)
        assert hit and hit <= ids_a
        assert not hit & ids_b
        # exact self-retrieval still works inside the namespace
        _, idx = eng.search(a[:8], tenant="A")
        np.testing.assert_array_equal(idx[:, 0], sorted(ids_a)[:8])

    def test_unknown_tenant_matches_nothing(self, backend):
        eng, db = make_engine(backend)
        scores, idx = eng.search(db[:4], tenant="never-added")
        assert (idx == -1).all()
        assert np.isinf(scores).all()

    def test_metadata_filter_composes_with_tenant(self, backend):
        eng, _ = make_engine(backend, n_docs=32)
        rng = np.random.default_rng(4)
        vecs = rng.normal(size=(30, D)).astype(np.float32)
        meta = [{"shard": j % 3, "lang": "en" if j % 2 else "de"}
                for j in range(30)]
        eng.add_docs(vecs, tenant="A", metadata=meta)
        eng.add_docs(vecs, tenant="B", metadata=meta)
        _, idx = eng.search(vecs[:6], tenant="A",
                            filter={"shard": {"$eq": 1}, "lang": "en"})
        hit = [int(i) for i in idx.ravel() if i >= 0]
        assert hit
        for i in hit:
            assert eng.store.tenant_of(i) == "A"
            md = eng.store.metadata_of(i)
            assert md["shard"] == 1 and md["lang"] == "en"

    def test_isolation_survives_delete_and_compaction(self, backend):
        eng, db = make_engine(backend, compact_dead_frac=0.3)
        rng = np.random.default_rng(5)
        a = rng.normal(size=(30, D)).astype(np.float32)
        b = rng.normal(size=(30, D)).astype(np.float32)
        ids_a = eng.add_docs(a, tenant="A")
        eng.add_docs(b, tenant="B")
        # kill most of the tenantless pool and half of A, then force the
        # rebuild safe point — compaction remaps every surviving id
        eng.delete_docs(np.arange(0, 180))
        eng.delete_docs(ids_a[:15])
        eng.maybe_rebuild(force=True)
        assert eng.stats.n_compactions >= 1
        _, idx = eng.search(np.concatenate([a[15:19], b[:4]]), tenant="A")
        hit = [int(i) for i in idx.ravel() if i >= 0]
        assert hit
        for i in hit:
            assert eng.store.tenant_of(i) == "A"
        # the deleted half of A stays gone: its vectors no longer
        # self-retrieve exactly
        _, idx = eng.search(a[:4], tenant="A")
        for i in idx.ravel():
            if i >= 0:
                assert eng.store.tenant_of(int(i)) == "A"

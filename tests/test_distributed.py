"""Distributed search + sharded lowering tests.

These run in a *subprocess* with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps seeing the single real CPU device.
"""

import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_search_matches_single_device():
    out = run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import (make_schedule, build_index, stage_dims,
                                progressive_search, sharded_progressive_search,
                                top1_accuracy)
        rng = np.random.default_rng(0)
        N, D, Q = 4096, 128, 32
        db = rng.normal(size=(N, D)).astype(np.float32)
        gt = rng.choice(N, Q, replace=False)
        q = db[gt] + 0.05 * rng.normal(size=(Q, D)).astype(np.float32)
        sched = make_schedule(16, 128, 16)
        idx = build_index(db, stage_dims(sched))
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ('data',))
        sg, cg = sharded_progressive_search(
            mesh, jnp.asarray(q), jnp.asarray(db), sched,
            sq_prefix=idx['sq_prefix'], index_dims=stage_dims(sched),
            block_n=512, mode='global')
        ss, cs = progressive_search(
            jnp.asarray(q), jnp.asarray(db), sched,
            sq_prefix=idx['sq_prefix'], index_dims=stage_dims(sched),
            block_n=512)
        # global mode must match single-device per-query results exactly
        assert (np.asarray(cg[:, 0]) == np.asarray(cs[:, 0])).mean() > 0.97
        sl, cl = sharded_progressive_search(
            mesh, jnp.asarray(q), jnp.asarray(db), sched,
            sq_prefix=idx['sq_prefix'], index_dims=stage_dims(sched),
            block_n=512, mode='local')
        # local mode: recall >= per-query variant
        acc_l = float(top1_accuracy(cl, jnp.asarray(gt)))
        acc_s = float(top1_accuracy(cs, jnp.asarray(gt)))
        assert acc_l >= acc_s - 1e-9
        print('OK', acc_l, acc_s)
    """)
    assert "OK" in out


def test_staged_search_matches_regular():
    """bf16 staged-index search == f32 regular search on a spectrum corpus."""
    out = run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import make_schedule, top1_accuracy
        from repro.core.distributed import (build_sharded_search_staged,
                                            sharded_progressive_search)
        rng = np.random.default_rng(0)
        N, D, Q = 4096, 128, 32
        scales = (1 + np.arange(D)) ** -0.3
        db = (rng.normal(size=(N, D)) * scales).astype(np.float32)
        gt = rng.choice(N, Q, replace=False)
        q = db[gt] + 0.2 * scales * rng.normal(size=(Q, D)).astype(np.float32)
        sched = make_schedule(32, 128, 32)
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ('data',))
        db0 = jnp.asarray(db[:, :32], jnp.bfloat16)
        sqp = jnp.sum(jnp.asarray(db[:, :32])**2, axis=1, keepdims=True)
        fn = build_sharded_search_staged(mesh, sched, N)
        s, c = jax.jit(fn)(jnp.asarray(q), db0, jnp.asarray(db), sqp)
        s2, c2 = sharded_progressive_search(
            mesh, jnp.asarray(q), jnp.asarray(db), sched, block_n=512)
        agree = float((np.asarray(c[:, 0]) == np.asarray(c2[:, 0])).mean())
        assert agree > 0.95, agree
        print('OK', agree)
    """)
    assert "OK" in out


def test_moe_ep_matches_single_device():
    """shard_map EP dispatch == single-device MoE (generous capacity)."""
    out = run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig
        from repro.layers.moe import moe_apply, moe_init
        from repro.sharding.specs import make_ctx
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                        capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = moe_init(key, 64, cfg, 'swiglu', jnp.float32)
        x = jax.random.normal(key, (4, 16, 64))
        y_ref, _ = moe_apply(p, x, cfg, 'swiglu')
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ('data', 'model'))
        ctx = make_ctx(mesh)
        with mesh:
            y_ep, _ = jax.jit(
                lambda p, x: moe_apply(p, x, cfg, 'swiglu', ctx=ctx))(p, x)
        err = float(jnp.abs(y_ep - y_ref).max())
        assert err < 0.05, err   # bf16 wire quantization
        # gradients flow through the EP path
        g = jax.grad(lambda p, x: moe_apply(
            p, x, cfg, 'swiglu', ctx=ctx)[0].sum())(p, x)
        assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
        print('OK', err)
    """)
    assert "OK" in out


def test_lm_train_step_lowers_on_2d_mesh():
    """Reduced LM lowers + compiles with FSDP x TP sharding on a 4x2 mesh."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import lm as LM
        from repro.optim import adamw_init
        from repro.sharding.specs import make_ctx
        from repro.optim.adamw import opt_state_logical

        cfg = get_arch('mistral-nemo-12b').SMOKE_CONFIG
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4, 2), ('data', 'model'))
        ctx = make_ctx(mesh)
        params = jax.eval_shape(lambda: LM.init_lm(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda: adamw_init(params))
        logical = LM.lm_param_logical(cfg)
        pshard = ctx.tree_shardings(logical, params)
        oshard = ctx.tree_shardings(opt_state_logical(logical), opt)
        bshard = {'tokens': NamedSharding(mesh, P(('data',)))}

        from repro.train.loop import make_train_step
        step = make_train_step(lambda p, b: LM.lm_loss(p, b, cfg, ctx),
                               donate=False)
        batch = {'tokens': jax.ShapeDtypeStruct((8, 17), jnp.int32)}
        with mesh:
            lowered = jax.jit(
                lambda p, o, b: step(p, o, b),
                in_shardings=(pshard, oshard, bshard),
            ).lower(params, opt, batch)
            compiled = lowered.compile()
        txt = compiled.as_text()
        has_collective = any(op in txt for op in
                             ('all-reduce', 'all-gather', 'reduce-scatter'))
        assert has_collective, 'expected collectives in SPMD module'
        ca = compiled.cost_analysis()
        if isinstance(ca, list):   # jax 0.4.x returns [dict]
            ca = ca[0]
        print('OK compiled; flops=', ca['flops'])
    """)
    assert "OK compiled" in out

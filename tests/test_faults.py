"""Fault-tolerance layer: WAL durability, checksummed snapshots with
corruption fallback, crash recovery (including a SIGKILL'd subprocess),
driver supervision (dead + hung threads, capped backoff, give-up), rebuild
retries, poison-batch bisection, and the config/index compatibility gate.

Everything here is deterministic: failures come from the seeded
`repro.engine.faults.FaultPlan` harness or from explicit file surgery, never
from racing real hardware faults.  Every blocking wait carries a timeout so
a broken recovery path fails the test instead of hanging the suite.
"""

import json
import os
import signal
import subprocess
import sys
import time
import zlib

import numpy as np
import pytest

from repro.engine import (
    DriverStopped,
    EngineDriver,
    FaultPlan,
    FaultToleranceConfig,
    IndexMismatch,
    InjectedFault,
    MutationWAL,
    PoisonError,
    RequestFailed,
    RetrievalEngine,
    Supervisor,
    SupervisorGaveUp,
    WALError,
)
from repro.checkpoint import CorruptCheckpoint

RNG = np.random.default_rng(41)
D = 16
WAIT = 30.0

# tight supervision knobs so watchdog tests converge in milliseconds
FAST_FT = dict(heartbeat_timeout_s=0.15, backoff_initial_s=0.01,
               backoff_max_s=0.05)


def make_engine(n_docs=48, fault=None, **kw):
    kw.setdefault("d_start", 4)
    kw.setdefault("k0", 8)
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("capacity", 64)
    kw.setdefault("block_n", 32)
    eng = RetrievalEngine(D, fault=fault, **kw)
    db = RNG.normal(size=(n_docs, D)).astype(np.float32)
    if n_docs:
        eng.add_docs(db)
    return eng, db


def wait_until(pred, timeout=WAIT, msg="condition"):
    deadline = time.perf_counter() + timeout
    while not pred():
        assert time.perf_counter() < deadline, f"timed out waiting: {msg}"
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# fault-plan parsing + firing
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_empty_spec_is_inert(self):
        plan = FaultPlan.parse("")
        assert plan.empty
        for _ in range(3):
            plan.check("dispatch")
        assert plan.summary() == {"calls": {}, "fired": {}}

    def test_once_fires_exactly_on_kth_call(self):
        plan = FaultPlan.parse("rebuild:error@once=2")
        plan.check("rebuild")
        with pytest.raises(InjectedFault):
            plan.check("rebuild")
        plan.check("rebuild")                     # 3rd call: quiet again
        assert plan.summary()["fired"] == {"rebuild:error": 1}

    def test_first_and_every_qualifiers(self):
        plan = FaultPlan.parse("wal_write:error@first=2")
        for _ in range(2):
            with pytest.raises(InjectedFault):
                plan.check("wal_write")
        plan.check("wal_write")
        plan = FaultPlan.parse("wal_write:error@every=2")
        plan.check("wal_write")
        with pytest.raises(InjectedFault):
            plan.check("wal_write")

    def test_probabilistic_rule_replays_identically(self):
        def draw():
            plan = FaultPlan.parse("dispatch:error@p=0.5", seed=9)
            out = []
            for _ in range(32):
                try:
                    plan.check("dispatch")
                    out.append(0)
                except InjectedFault:
                    out.append(1)
            return out

        assert draw() == draw()
        assert 0 < sum(draw()) < 32

    def test_poison_fires_only_on_marker_query(self):
        plan = FaultPlan.parse("dispatch:poison@v=123.0")
        clean = np.zeros(4, np.float32)
        bad = clean.copy()
        bad[0] = 123.0
        plan.check("dispatch", queries=[clean, clean])
        with pytest.raises(PoisonError):
            plan.check("dispatch", queries=[clean, bad])

    def test_bad_specs_rejected(self):
        for spec in ("nowhere:error@once=1",       # unknown site
                     "dispatch:melt@once=1",       # unknown action
                     "dispatch:error",             # never fires
                     "rebuild:poison@v=1.0",       # poison off-dispatch
                     "dispatch:poison",            # poison without marker
                     "dispatch:error@zap=1"):      # unknown qualifier
            with pytest.raises(ValueError):
                FaultPlan.parse(spec)

    def test_config_parses_spec_eagerly(self):
        with pytest.raises(ValueError):
            FaultToleranceConfig(inject="dispatch:bogus@once=1")


# ---------------------------------------------------------------------------
# WAL unit behavior
# ---------------------------------------------------------------------------
class TestMutationWAL:
    def test_append_replay_round_trip(self, tmp_path):
        wal = MutationWAL(str(tmp_path))
        assert wal.append("add", {"start": 0, "n": 2}) == 0
        assert wal.append("delete", {"ids": [1]}) == 1
        wal.close()
        wal2 = MutationWAL(str(tmp_path))
        recs = list(wal2.replay())
        assert [(r.seq, r.kind) for r in recs] == [(0, "add"), (1, "delete")]
        assert recs[1].payload["ids"] == [1]
        assert wal2.last_seq == 1 and not wal2.torn_tail

    def test_replay_after_seq_skips_prefix(self, tmp_path):
        wal = MutationWAL(str(tmp_path))
        for i in range(5):
            wal.append("add", {"i": i})
        assert [r.seq for r in wal.replay(after_seq=2)] == [3, 4]

    def test_torn_tail_truncated_and_appendable(self, tmp_path):
        wal = MutationWAL(str(tmp_path))
        wal.append("add", {"i": 0})
        wal.append("add", {"i": 1})
        wal.close()
        [log] = [p for p in os.listdir(tmp_path) if p.endswith(".log")]
        path = os.path.join(tmp_path, log)
        # crash mid-append: chop the last record in half
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size - 7)
        wal2 = MutationWAL(str(tmp_path))
        assert wal2.torn_tail
        assert wal2.last_seq == 0                 # seq 1 was torn away
        assert wal2.append("add", {"i": "next"}) == 1
        assert [r.seq for r in wal2.replay()] == [0, 1]

    def test_corrupt_record_stops_replay(self, tmp_path):
        wal = MutationWAL(str(tmp_path))
        wal.append("add", {"i": 0})
        off_ok = os.path.getsize(
            os.path.join(tmp_path, "wal-000000000000.log"))
        wal.append("add", {"i": 1})
        wal.close()
        path = os.path.join(tmp_path, "wal-000000000000.log")
        with open(path, "r+b") as f:             # flip a payload byte
            f.seek(off_ok + 9)
            byte = f.read(1)
            f.seek(off_ok + 9)
            f.write(bytes([byte[0] ^ 0xFF]))
        wal2 = MutationWAL(str(tmp_path))
        assert [r.seq for r in wal2.replay()] == [0]
        assert wal2.torn_tail

    def test_rotate_and_prune(self, tmp_path):
        wal = MutationWAL(str(tmp_path))
        for i in range(3):
            wal.append("add", {"i": i})
        wal.rotate()
        assert wal.lag == 0 and wal.n_segments == 2
        wal.append("add", {"i": 3})
        assert wal.lag == 1
        # seqs 0..2 are covered: the old segment goes, the active one stays
        assert wal.prune(2) == 1
        assert wal.n_segments == 1
        assert [r.seq for r in wal.replay()] == [3]
        wal.close()

    def test_closed_wal_refuses_appends(self, tmp_path):
        wal = MutationWAL(str(tmp_path))
        wal.close()
        with pytest.raises(WALError, match="closed"):
            wal.append("add", {})


# ---------------------------------------------------------------------------
# checkpoint corruption detection
# ---------------------------------------------------------------------------
class TestCorruptCheckpoint:
    def test_flipped_array_byte_detected(self, tmp_path):
        from repro.checkpoint import load_arrays, save_arrays

        save_arrays(str(tmp_path), 1, {"w": np.arange(32, dtype=np.float32)})
        step_dir = os.path.join(tmp_path, "step_00000001")
        npz = os.path.join(step_dir, "arrays.npz")
        blob = bytearray(open(npz, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(blob))
        with pytest.raises(CorruptCheckpoint):
            load_arrays(str(tmp_path), step=1)

    def test_manifest_garbage_detected(self, tmp_path):
        from repro.checkpoint import load_arrays, save_arrays

        save_arrays(str(tmp_path), 1, {"w": np.zeros(4, np.float32)})
        manifest = os.path.join(tmp_path, "step_00000001",
                                "manifest.msgpack")
        open(manifest, "wb").write(b"\xc1 not msgpack")
        with pytest.raises(CorruptCheckpoint):
            load_arrays(str(tmp_path), step=1)


# ---------------------------------------------------------------------------
# engine durability: WAL + snapshots + recover()
# ---------------------------------------------------------------------------
def durable_engine(tmp_path, n_docs=48, **kw):
    # durability first, THEN the seed corpus: every row is WAL-covered
    eng, _ = make_engine(n_docs=0, **kw)
    eng.enable_durability(str(tmp_path))
    db = RNG.normal(size=(n_docs, D)).astype(np.float32)
    if n_docs:
        eng.add_docs(db)
    return eng, db


class TestRecovery:
    def test_wal_only_recovery_no_snapshot(self, tmp_path):
        eng, db = durable_engine(tmp_path)
        extra = RNG.normal(size=(4, D)).astype(np.float32)
        ids = eng.add_docs(extra)
        eng.delete_docs(ids[:1])
        eng.wal.close()

        eng2, _ = make_engine(n_docs=0)
        report = eng2.recover(str(tmp_path))
        assert report["status"] == "ok"
        assert report["snapshot_step"] is None
        assert report["replayed"] == 3            # seed add + add + delete
        assert eng2.n_docs == eng.n_docs
        np.testing.assert_array_equal(
            eng2.search(db[:4])[1], eng.search(db[:4])[1])

    def test_snapshot_plus_tail_replay(self, tmp_path):
        eng, db = durable_engine(tmp_path)
        eng.search(db[:2])                        # build index state
        eng.save_snapshot()
        post = RNG.normal(size=(3, D)).astype(np.float32)
        ids = eng.add_docs(post)                  # lands in the WAL tail
        eng.delete_docs([0])
        eng.wal.close()

        eng2, _ = make_engine(n_docs=0)
        report = eng2.recover(str(tmp_path))
        assert report["snapshot_step"] is not None
        assert report["replayed"] == 2
        assert report["fallbacks"] == 0
        assert eng2.n_docs == eng.n_docs
        # tail-added docs retrievable; deleted doc stays deleted
        _, idx = eng2.search(post)
        np.testing.assert_array_equal(idx[:, 0], ids)
        assert 0 not in eng2.search(db[:1])[1][0]

    def test_recovered_engine_keeps_logging(self, tmp_path):
        eng, db = durable_engine(tmp_path)
        eng.wal.close()
        eng2, _ = make_engine(n_docs=0)
        eng2.recover(str(tmp_path))
        more = RNG.normal(size=(2, D)).astype(np.float32)
        ids = eng2.add_docs(more)
        eng2.wal.close()
        eng3, _ = make_engine(n_docs=0)
        eng3.recover(str(tmp_path))
        _, idx = eng3.search(more)
        np.testing.assert_array_equal(idx[:, 0], ids)

    def test_corrupt_newest_snapshot_falls_back(self, tmp_path):
        eng, db = durable_engine(tmp_path)
        eng.save_snapshot()
        eng.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
        path2 = eng.save_snapshot()
        # corrupt the NEWEST snapshot's arrays
        npz = os.path.join(path2, "arrays.npz")
        blob = bytearray(open(npz, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(blob))
        eng.wal.close()

        eng2, _ = make_engine(n_docs=0)
        report = eng2.recover(str(tmp_path))
        assert report["fallbacks"] == 1
        # the older snapshot + the 'add' WAL record reconstruct everything
        assert report["replayed"] >= 1
        assert eng2.n_docs == eng.n_docs

    def test_torn_wal_tail_reported(self, tmp_path):
        eng, _ = durable_engine(tmp_path)
        eng.wal.close()
        wal_dir = os.path.join(tmp_path, "wal")
        [log] = sorted(p for p in os.listdir(wal_dir) if p.endswith(".log"))
        path = os.path.join(wal_dir, log)
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) - 3)
        eng2, _ = make_engine(n_docs=0)
        report = eng2.recover(str(tmp_path))
        assert report["wal_truncated"]
        assert eng2.n_docs == 0                   # seed add record was torn

    def test_recover_rejects_mismatched_config(self, tmp_path):
        eng, _ = durable_engine(tmp_path)
        eng.save_snapshot()
        eng.wal.close()
        other = RetrievalEngine(D, d_start=4, k0=8, buckets=(1,),
                                capacity=64, backend="quantized",
                                backend_opts={"min_rebuild_rows": 16})
        with pytest.raises(IndexMismatch, match="backend"):
            other.recover(str(tmp_path))

    def test_wal_validation_precedes_logging(self, tmp_path):
        """A rejected mutation must not leave a WAL record behind (it
        would diverge on replay)."""
        eng, _ = durable_engine(tmp_path)
        seq_before = eng.wal.last_seq
        with pytest.raises(ValueError):
            eng.add_docs(np.zeros((2, D + 3), np.float32))
        with pytest.raises(IndexError):
            eng.delete_docs([10_000])
        assert eng.wal.last_seq == seq_before

    def test_snapshot_requires_durability(self):
        eng, _ = make_engine()
        with pytest.raises(RuntimeError, match="durability"):
            eng.save_snapshot()

    def test_snapshot_prunes_wal_segments(self, tmp_path):
        eng, _ = durable_engine(tmp_path, fault=FaultToleranceConfig(
            snapshot_keep=1))
        for _ in range(3):
            eng.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
            eng.save_snapshot()
        assert eng.wal.lag == 0
        # keep=1: only the newest snapshot's tail segment (+ active) remain
        assert eng.wal.n_segments <= 2

    def test_tenant_and_metadata_survive_recovery(self, tmp_path):
        eng, _ = make_engine(n_docs=0)
        eng.enable_durability(str(tmp_path))
        a = RNG.normal(size=(3, D)).astype(np.float32)
        b = RNG.normal(size=(3, D)).astype(np.float32)
        ids_a = eng.add_docs(a, tenant="alice",
                             metadata=[{"lang": "en"}] * 3)
        eng.add_docs(b, tenant="bob", metadata=[{"lang": "fr"}] * 3)
        eng.save_snapshot()
        c = RNG.normal(size=(2, D)).astype(np.float32)
        ids_c = eng.add_docs(c, tenant="alice",
                             metadata=[{"lang": "de"}] * 2)
        eng.wal.close()

        eng2, _ = make_engine(n_docs=0)
        eng2.recover(str(tmp_path))
        assert sorted(eng2.store.tenants()) == ["alice", "bob"]
        assert eng2.store.tenant_doc_count("alice") == 5
        _, idx = eng2.search(c[:1], tenant="alice", filter={"lang": "de"})
        assert idx[0, 0] == ids_c[0]
        # snapshot-covered rows kept their tenant column too
        _, idx = eng2.search(a[:1], tenant="alice")
        assert idx[0, 0] == ids_a[0]


class TestSubprocessCrash:
    """The durability contract against real process death: a child engine
    acknowledges mutations (fsync'd WAL), gets SIGKILLed mid-churn, and the
    parent must recover every acknowledged doc — no lost acks, no tombstone
    resurrection."""

    CHILD = r"""
import os, sys, numpy as np
sys.path.insert(0, {src!r})
from repro.engine import RetrievalEngine

eng = RetrievalEngine({d}, d_start=4, k0=8, buckets=(1,), capacity=64,
                      block_n=32)
eng.enable_durability({state!r})
rng = np.random.default_rng(5)
ack = open(os.path.join({state!r}, "acked.log"), "a")
os.write(1, b"ready\n")
i = 0
while True:
    vecs = rng.normal(size=(2, {d})).astype(np.float32) + i
    ids = eng.add_docs(vecs)
    if i % 5 == 4:
        eng.delete_docs(ids[:1])
        note = f"del {{ids[0]}}\n"
    else:
        note = ""
    # ack AFTER the engine returned: the WAL record is already fsync'd
    ack.write(f"add {{ids[0]}} {{ids[1]}}\n" + note)
    ack.flush(); os.fsync(ack.fileno())
    i += 1
"""

    @pytest.mark.slow
    def test_sigkill_loses_no_acked_mutation(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        state = str(tmp_path)
        code = self.CHILD.format(src=os.path.abspath(src), d=D, state=state)
        proc = subprocess.Popen([sys.executable, "-c", code],
                                stdout=subprocess.PIPE)
        try:
            assert proc.stdout.readline().strip() == b"ready"
            # let it churn, then kill it mid-flight — no warning, no flush
            time.sleep(0.6)
            os.kill(proc.pid, signal.SIGKILL)
            proc.wait(timeout=WAIT)
        finally:
            if proc.poll() is None:
                proc.kill()

        acked_adds, acked_dels = set(), set()
        with open(os.path.join(state, "acked.log")) as f:
            for line in f:
                kind, *ids = line.split()
                if kind == "add":
                    acked_adds.update(int(x) for x in ids)
                else:
                    acked_dels.add(int(ids[0]))
        assert len(acked_adds) > 4, "child died before doing real work"

        eng, _ = make_engine(n_docs=0)
        report = eng.recover(state)
        assert report["status"] == "ok"
        live = acked_adds - acked_dels
        for doc_id in sorted(live):
            assert eng.store.is_live(doc_id), \
                f"acked doc {doc_id} lost by recovery"
        for doc_id in sorted(acked_dels):
            assert not eng.store.is_live(doc_id), \
                f"tombstoned doc {doc_id} resurrected"
        # recovered corpus actually serves: every live doc is retrievable
        some = sorted(live)[:4]
        q = np.stack([np.asarray(eng.store.db[i]) for i in some])
        _, idx = eng.search(q)
        np.testing.assert_array_equal(idx[:, 0], some)


# ---------------------------------------------------------------------------
# driver supervision
# ---------------------------------------------------------------------------
class TestSupervision:
    def test_supervised_crash_restarts_and_serves(self, tmp_path):
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:crash@once=1", **FAST_FT))
        driver = EngineDriver(eng, max_wait_ms=0.0)
        driver.start(supervised=True)
        sup = Supervisor(driver).start()
        try:
            bad = driver.submit(db[0])
            # the crashed dispatch fails its own chunk...
            with pytest.raises(DriverStopped):
                bad.result(WAIT)
            # ...the supervisor revives the thread and service resumes
            wait_until(lambda: driver.stats.n_restarts >= 1,
                       msg="supervisor restart")
            res = driver.retrieve(db[1], timeout=WAIT)
            assert res.doc_ids[0] == 1
            assert driver.stats.n_driver_crashes == 1
            assert driver.supervisor is sup
        finally:
            sup.stop()
            driver.stop()

    def test_pending_queue_survives_crash(self, tmp_path):
        """Requests queued BEHIND the crashing batch are served by the
        replacement thread — nobody but the crashed chunk pays."""
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:crash@once=1", **FAST_FT))
        # short batching window so queued requests dispatch promptly once
        # the replacement thread takes over
        driver = EngineDriver(eng, max_wait_ms=5.0, max_queue=64)
        futs = [driver.submit(db[i]) for i in range(5)]
        driver.start(supervised=True)
        sup = Supervisor(driver).start()
        try:
            survivors = [f.result(WAIT).doc_ids[0] for f in futs
                         if f.exception(WAIT) is None]
            assert len(survivors) >= 1            # replacement served them
            assert driver.stats.n_driver_crashes == 1
        finally:
            sup.stop()
            driver.stop()

    def test_hung_thread_detected_and_replaced(self):
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:hang@once=1,s=1.5", **FAST_FT))
        driver = EngineDriver(eng, max_wait_ms=0.0)
        driver.start(supervised=True)
        sup = Supervisor(driver).start()
        try:
            slow = driver.submit(db[0])          # dispatch wedges 1.5s
            time.sleep(0.05)
            quick = driver.submit(db[1])         # queues behind the hang
            res = quick.result(WAIT)             # replacement must serve it
            assert res.doc_ids[0] == 1
            assert driver.stats.n_restarts >= 1
            assert sup.last_cause == "hung"
            # the wedged thread eventually finishes its own dispatch and
            # stands down; its client still gets the (late) answer
            assert slow.result(WAIT).doc_ids[0] == 0
        finally:
            sup.stop()
            driver.stop()

    def test_crash_storm_gives_up_after_max_restarts(self):
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:crash@every=1", max_restarts=2, **FAST_FT))
        driver = EngineDriver(eng, max_wait_ms=0.0, max_queue=64)
        driver.start(supervised=True)
        sup = Supervisor(driver).start()
        try:
            futs = [driver.submit(db[i % len(db)]) for i in range(12)]
            wait_until(lambda: sup.gave_up, msg="supervisor give-up")
            for f in futs:
                with pytest.raises(DriverStopped):
                    f.result(WAIT)
            with pytest.raises(DriverStopped):
                driver.submit(db[0])
            assert driver.stats.n_restarts == 2
            with pytest.raises(SupervisorGaveUp):
                driver.stop()
        finally:
            sup.stop()

    def test_unsupervised_crash_stays_fatal(self):
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:crash@once=1"))
        driver = EngineDriver(eng, max_wait_ms=0.0).start()
        fut = driver.submit(db[0])
        with pytest.raises(DriverStopped):
            fut.result(WAIT)
        wait_until(lambda: not driver.running, msg="driver going fatal")
        with pytest.raises(DriverStopped):
            driver.submit(db[1])
        with pytest.raises(BaseException, match="injected crash"):
            driver.stop()

    def test_manual_restart_without_supervisor(self):
        eng, db = make_engine(fault=FaultToleranceConfig(
            inject="dispatch:crash@once=1"))
        driver = EngineDriver(eng, max_wait_ms=0.0)
        driver.start(supervised=True)
        try:
            bad = driver.submit(db[0])
            with pytest.raises(DriverStopped):
                bad.result(WAIT)
            wait_until(lambda: driver.health()["crashed"],
                       msg="crash recorded")
            assert driver.restart()
            assert driver.retrieve(db[2], timeout=WAIT).doc_ids[0] == 2
            assert driver.stats.n_restarts == 1
        finally:
            driver.stop()

    def test_restart_refuses_non_running_driver(self):
        eng, _ = make_engine()
        driver = EngineDriver(eng)
        assert not driver.restart()               # never started
        driver.start()
        driver.stop()
        assert not driver.restart()               # already stopped

    def test_health_snapshot_fields(self):
        eng, db = make_engine()
        with EngineDriver(eng, max_wait_ms=0.0) as driver:
            driver.retrieve(db[0], timeout=WAIT)
            h = driver.health()
        assert h["state"] in ("running", "stopped")
        assert h["thread_alive"] in (True, False)
        assert h["n_pending"] == 0
        assert h["heartbeat_age_s"] >= 0.0
        assert not h["crashed"]


# ---------------------------------------------------------------------------
# rebuild retries
# ---------------------------------------------------------------------------
class TestRebuildRetry:
    def make_bg_engine(self, inject, retries=3):
        # warm the initial (sync) build with an inert plan, THEN arm the
        # faults and grow the corpus: only background rebuilds fail
        eng = RetrievalEngine(
            D, d_start=4, k0=8, buckets=(1, 2), capacity=256, block_n=32,
            backend="quantized", backend_opts={"min_rebuild_rows": 8},
            rebuild_mode="background",
            fault=FaultToleranceConfig(rebuild_retries=retries))
        db = RNG.normal(size=(48, D)).astype(np.float32)
        eng.add_docs(db)
        eng.search(db[:1])
        assert eng.stats.n_rebuilds == 1
        eng.faults = FaultPlan.parse(inject)
        eng.add_docs(RNG.normal(size=(48, D)).astype(np.float32))
        return eng, db

    def test_transient_failures_retried_to_success(self):
        eng, db = self.make_bg_engine("rebuild:error@first=2")
        deadline = time.perf_counter() + WAIT
        while eng.stats.n_rebuilds < 2:          # beyond the warm build
            eng.maybe_rebuild()
            assert time.perf_counter() < deadline, "rebuild never adopted"
            time.sleep(0.01)
        assert eng.stats.n_rebuild_failures == 2
        _, idx = eng.search(db[:4])
        np.testing.assert_array_equal(idx[:, 0], np.arange(4))

    def test_persistent_failure_escalates_past_budget(self):
        eng, _ = self.make_bg_engine("rebuild:error@first=50", retries=2)
        deadline = time.perf_counter() + WAIT
        with pytest.raises(RuntimeError, match="failed .* times in a row"):
            while time.perf_counter() < deadline:
                eng.maybe_rebuild()
                time.sleep(0.01)
        assert eng.stats.n_rebuild_failures == 3  # budget 2 + the last straw


# ---------------------------------------------------------------------------
# poison isolation by batch bisection
# ---------------------------------------------------------------------------
class TestPoisonBisection:
    def test_poison_request_fails_alone(self):
        eng, db = make_engine(buckets=(1, 2, 4), fault=FaultToleranceConfig(
            inject="dispatch:poison@v=777.0"))
        poison = db[1].copy()
        poison[0] = 777.0
        driver = EngineDriver(eng, max_wait_ms=60_000)   # unstarted: inline
        futs = [driver.submit(db[0]), driver.submit(poison),
                driver.submit(db[2]), driver.submit(db[3])]
        driver.stop(drain=True)
        with pytest.raises(RequestFailed, match="bisection"):
            futs[1].result(0)
        for i in (0, 2, 3):
            assert futs[i].result(0).doc_ids[0] == i
        assert driver.stats.n_quarantined == 1
        assert driver.stats.n_bisections >= 1
        assert driver.stats.n_completed == 3

    def test_bisect_disabled_fails_whole_batch(self):
        eng, db = make_engine(buckets=(1, 2, 4), fault=FaultToleranceConfig(
            inject="dispatch:poison@v=777.0", poison_bisect=False))
        poison = db[1].copy()
        poison[0] = 777.0
        driver = EngineDriver(eng, max_wait_ms=60_000)
        futs = [driver.submit(db[0]), driver.submit(poison),
                driver.submit(db[2]), driver.submit(db[3])]
        driver.stop(drain=True)
        for f in futs:
            with pytest.raises(PoisonError):
                f.result(0)
        assert driver.stats.n_quarantined == 0

    def test_two_poisons_both_isolated(self):
        eng, db = make_engine(buckets=(1, 2, 4), fault=FaultToleranceConfig(
            inject="dispatch:poison@v=777.0"))
        p1, p2 = db[0].copy(), db[3].copy()
        p1[0] = p2[0] = 777.0
        driver = EngineDriver(eng, max_wait_ms=60_000)
        futs = [driver.submit(p1), driver.submit(db[1]),
                driver.submit(db[2]), driver.submit(p2)]
        driver.stop(drain=True)
        for i in (0, 3):
            with pytest.raises(RequestFailed):
                futs[i].result(0)
        for i in (1, 2):
            assert futs[i].result(0).doc_ids[0] == i
        assert driver.stats.n_quarantined == 2

    def test_singleton_failure_propagates_raw(self):
        """A failing batch of ONE is not 'isolated' — the client sees the
        real exception (same contract as before bisection existed)."""
        eng, db = make_engine(buckets=(1,), fault=FaultToleranceConfig(
            inject="dispatch:poison@v=777.0"))
        poison = db[0].copy()
        poison[0] = 777.0
        driver = EngineDriver(eng, max_wait_ms=60_000)
        fut = driver.submit(poison)
        driver.stop(drain=True)
        with pytest.raises(PoisonError):
            fut.result(0)
        assert driver.stats.n_quarantined == 0


# ---------------------------------------------------------------------------
# index/config compatibility gate
# ---------------------------------------------------------------------------
def _backend_variants():
    # mirror tests/test_backends.py's six variants without importing it
    # (pytest collects test modules standalone)
    return [
        ("flat", "flat", {}),
        ("ivf", "ivf", dict(n_lists=6, n_probe=3, min_index_rows=16,
                            min_rebuild_rows=8)),
        ("ivf_kernel", "ivf", dict(n_lists=6, n_probe=3, min_index_rows=16,
                                   min_rebuild_rows=8, use_kernel=True,
                                   kernel_block_m=16)),
        ("ivf_pq", "ivf", dict(n_lists=6, n_probe=3, min_index_rows=16,
                               min_rebuild_rows=8, use_kernel=True,
                               kernel_block_m=16, stage0_dtype="pq")),
        ("quantized", "quantized", dict(min_rebuild_rows=8)),
        ("quantized_pq", "quantized", dict(min_rebuild_rows=8, codec="pq")),
    ]


class TestIndexCompatibility:
    @pytest.mark.parametrize("variant,backend,opts", _backend_variants())
    def test_load_rejects_wrong_dim(self, tmp_path, variant, backend, opts):
        eng = RetrievalEngine(D, d_start=4, k0=8, buckets=(1,), capacity=64,
                              block_n=32, backend=backend, backend_opts=opts)
        eng.add_docs(RNG.normal(size=(40, D)).astype(np.float32))
        eng.search(RNG.normal(size=(1, D)).astype(np.float32))
        assert eng.save_index(str(tmp_path)) is not None

        wrong = RetrievalEngine(D * 2, d_start=4, k0=8, buckets=(1,),
                                capacity=64, block_n=32, backend=backend,
                                backend_opts=opts)
        wrong.add_docs(RNG.normal(size=(40, D * 2)).astype(np.float32))
        with pytest.raises(IndexMismatch, match="d_emb"):
            wrong.load_index(str(tmp_path))

    @pytest.mark.parametrize("variant,backend,opts", _backend_variants())
    def test_load_rejects_wrong_backend_kind(self, tmp_path, variant,
                                             backend, opts):
        eng = RetrievalEngine(D, d_start=4, k0=8, buckets=(1,), capacity=64,
                              block_n=32, backend=backend, backend_opts=opts)
        eng.add_docs(RNG.normal(size=(40, D)).astype(np.float32))
        eng.search(RNG.normal(size=(1, D)).astype(np.float32))
        eng.save_index(str(tmp_path))

        other_kind = "quantized" if backend != "quantized" else "ivf"
        other_opts = (dict(min_rebuild_rows=8) if other_kind == "quantized"
                      else dict(n_lists=6, n_probe=3, min_index_rows=16,
                                min_rebuild_rows=8))
        other = RetrievalEngine(D, d_start=4, k0=8, buckets=(1,),
                                capacity=64, block_n=32, backend=other_kind,
                                backend_opts=other_opts)
        other.add_docs(RNG.normal(size=(40, D)).astype(np.float32))
        with pytest.raises(IndexMismatch, match="backend"):
            other.load_index(str(tmp_path))

    def test_round_trip_same_config_still_works(self, tmp_path):
        eng = RetrievalEngine(D, d_start=4, k0=8, buckets=(1,), capacity=64,
                              block_n=32, backend="quantized",
                              backend_opts=dict(min_rebuild_rows=8))
        db = RNG.normal(size=(40, D)).astype(np.float32)
        eng.add_docs(db)
        eng.search(db[:1])
        eng.save_index(str(tmp_path))
        twin = RetrievalEngine(D, d_start=4, k0=8, buckets=(1,),
                               capacity=64, block_n=32, backend="quantized",
                               backend_opts=dict(min_rebuild_rows=8))
        twin.add_docs(db)
        assert twin.load_index(str(tmp_path))
        np.testing.assert_array_equal(
            twin.search(db[:4])[1], eng.search(db[:4])[1])


# ---------------------------------------------------------------------------
# deep health over HTTP
# ---------------------------------------------------------------------------
class TestDeepHealth:
    def test_deep_healthz_reports_ft_state(self, tmp_path):
        import urllib.request

        from repro.serve import serve_in_thread

        eng, db = make_engine(n_docs=0)
        eng.enable_durability(str(tmp_path))
        eng.add_docs(RNG.normal(size=(8, D)).astype(np.float32))
        driver = EngineDriver(eng, max_wait_ms=1.0)
        driver.start(supervised=True)
        sup = Supervisor(driver).start()
        try:
            with serve_in_thread(eng, driver,
                                 require_tenant=False) as handle:
                with urllib.request.urlopen(
                        handle.url + "/healthz?deep=1", timeout=WAIT) as r:
                    payload = json.loads(r.read())
                with urllib.request.urlopen(
                        handle.url + "/healthz", timeout=WAIT) as r:
                    shallow = json.loads(r.read())
        finally:
            sup.stop()
            driver.stop()
        assert "deep" not in shallow
        deep = payload["deep"]
        assert deep["driver"]["state"] == "running"
        assert deep["driver"]["heartbeat_age_s"] >= 0.0
        assert deep["supervisor"]["attached"]
        assert deep["wal"]["last_seq"] == 0       # the one add above
        assert deep["last_recovery"] is None
        assert deep["n_quarantined"] == 0

"""Adaptive degradation policy + query-result cache (PR 8).

Covers: the AdaptivePolicy control loop on a fake clock, QueryCache
semantics (exact/near hits, LRU, structural invalidation), per-dispatch
SearchOverrides through every backend variant, the bit-for-bit guarantee
that an adaptive-enabled engine at level 0 matches the static path, the
driver integration (cache in front of the queue, level-keyed entries),
and the hypothesis property that a cached result is never served across
a ``store_generation`` / ``mask_epoch`` / rebuild bump.
"""

import numpy as np
import pytest

from repro.engine import (
    AdaptiveConfig,
    AdaptivePolicy,
    CacheConfig,
    EngineDriver,
    QueryCache,
    RetrievalEngine,
    SearchRequest,
)
from repro.engine.adaptive import SearchOverrides

RNG = np.random.default_rng(23)
D = 32
BACKENDS = ("flat", "ivf", "quantized", "ivf_kernel", "ivf_pq",
            "quantized_pq")


def opts_for(backend, **extra):
    base = {
        "flat": {},
        "ivf": dict(n_lists=12, n_probe=6, min_index_rows=32,
                    min_rebuild_rows=16),
        "ivf_kernel": dict(n_lists=12, n_probe=6, min_index_rows=32,
                           min_rebuild_rows=16, use_kernel=True,
                           kernel_block_m=16),
        "ivf_pq": dict(n_lists=12, n_probe=6, min_index_rows=32,
                       min_rebuild_rows=16, use_kernel=True,
                       kernel_block_m=16, stage0_dtype="pq"),
        "quantized": dict(min_rebuild_rows=16),
        "quantized_pq": dict(min_rebuild_rows=16, codec="pq"),
    }[backend]
    return {**base, **extra} or None


def engine_backend(backend):
    if backend.startswith("ivf"):
        return "ivf"
    if backend.startswith("quantized"):
        return "quantized"
    return backend


def make_engine(backend, n_docs=96, seed=7, **kw):
    opts = kw.pop("backend_opts", opts_for(backend))
    kw.setdefault("d_start", 8)
    kw.setdefault("k0", 16)
    kw.setdefault("buckets", (4,))
    kw.setdefault("capacity", 64)
    kw.setdefault("block_n", 64)
    eng = RetrievalEngine(D, backend=engine_backend(backend),
                          backend_opts=opts, **kw)
    db = np.random.default_rng(seed).normal(
        size=(n_docs, D)).astype(np.float32)
    eng.add_docs(db)
    return eng, db


ADAPTIVE = AdaptiveConfig(enabled=True, levels=2, min_d_start=4)


# ---------------------------------------------------------------------------
# AdaptivePolicy control loop (pure, fake clock)
# ---------------------------------------------------------------------------

def _policy(**kw):
    kw.setdefault("enabled", True)
    kw.setdefault("levels", 3)
    kw.setdefault("depth_high", 10)
    kw.setdefault("wait_high_ms", 50.0)
    kw.setdefault("escalate_factor", 2.0)
    kw.setdefault("recover_frac", 0.5)
    kw.setdefault("hysteresis_s", 1.0)
    return AdaptivePolicy(AdaptiveConfig(**kw))


class TestAdaptivePolicy:
    def test_target_level_depth_ladder(self):
        p = _policy()
        assert p.target_level(0, None) == 0
        assert p.target_level(9, None) == 0
        assert p.target_level(10, None) == 1
        assert p.target_level(20, None) == 2
        assert p.target_level(40, None) == 3
        assert p.target_level(10_000, None) == 3  # clamped at cfg.levels

    def test_wait_signal_alone_escalates(self):
        p = _policy()
        assert p.target_level(0, 49.0) == 0
        assert p.target_level(0, 50.0) == 1
        assert p.target_level(0, 100.0) == 2
        assert p.update(0, 60.0, now=0.0) == 1
        assert p.n_escalations == 1

    def test_depth_only_config_ignores_wait(self):
        p = _policy(wait_high_ms=None)
        assert p.target_level(0, 10_000.0) == 0

    def test_escalation_is_immediate_and_multi_level(self):
        p = _policy()
        assert p.update(40, None, now=0.0) == 3
        assert p.n_escalations == 3
        assert p.n_recoveries == 0

    def test_recovery_needs_continuous_dwell(self):
        p = _policy()
        p.update(40, None, now=0.0)
        # calm (depth < 0.5 * entry_depth(3)=20) starts the timer...
        assert p.update(5, None, now=1.0) == 3
        # ...but pressure resets it (30 >= 20 is not calm at level 3)
        assert p.update(30, None, now=1.5) == 3
        assert p.update(5, None, now=2.0) == 3
        assert p.update(5, None, now=2.9) == 3   # dwell 0.9 < 1.0
        assert p.update(5, None, now=3.0) == 2   # one level, not to 0
        assert p.n_recoveries == 1
        # each further step needs its own full dwell (timer resets on
        # every downward step: recovering from level N takes N dwells)
        assert p.update(0, None, now=3.5) == 2
        assert p.update(0, None, now=4.5) == 1
        assert p.update(0, None, now=5.0) == 1   # new dwell only started
        assert p.update(0, None, now=6.0) == 0
        assert p.n_recoveries == 3
        # at level 0 nothing to recover
        assert p.update(0, None, now=9.0) == 0

    def test_wait_pressure_blocks_recovery(self):
        p = _policy()
        p.update(20, None, now=0.0)
        assert p.level == 2
        # depth calm but wait p95 still above recover_frac * entry wait
        p.update(0, 90.0, now=1.0)
        p.update(0, 90.0, now=5.0)
        assert p.level == 2

    def test_summary_and_publish_use_plain_ints(self):
        from repro.obs import MetricsRegistry, parse_prometheus
        p = _policy()
        reg = MetricsRegistry()
        p.bind(reg)
        p.update(20, None, now=0.0)
        p.update(0, None, now=1.0)
        p.update(0, None, now=2.5)
        s = p.summary()
        assert s["level"] == 1 and s["n_escalations"] == 2
        assert s["n_recoveries"] == 1
        p.publish()
        m = parse_prometheus(reg.render_prometheus())
        assert m["repro_adaptive_transitions_total"][(("direction", "up"),)] == 2
        assert m["repro_adaptive_transitions_total"][(("direction", "down"),)] == 1
        assert m["repro_adaptive_level"][()] == 1


# ---------------------------------------------------------------------------
# QueryCache unit semantics
# ---------------------------------------------------------------------------

S0 = (1, 1, 0)


class TestQueryCache:
    def _q(self, seed=0):
        return np.random.default_rng(seed).normal(size=D).astype(np.float32)

    def test_exact_hit_round_trip(self):
        c = QueryCache(D, capacity=4)
        q = self._q()
        assert c.lookup(q, 3, None, 0, S0) is None
        c.insert(q, np.arange(3, dtype=np.float32), np.array([7, 8, 9]),
                 None, 0, S0)
        s, i, kind = c.lookup(q, 3, None, 0, S0)
        assert kind == "exact"
        np.testing.assert_array_equal(i, [7, 8, 9])
        np.testing.assert_array_equal(s, [0.0, 1.0, 2.0])
        assert c.hits_exact == 1 and c.misses == 1

    def test_wider_k_request_misses_narrow_entry(self):
        c = QueryCache(D, capacity=4)
        q = self._q()
        c.insert(q, np.zeros(5, np.float32), np.arange(5), None, 0, S0)
        assert c.lookup(q, 8, None, 0, S0) is None          # entry.k=5 < 8
        s, i, _ = c.lookup(q, 2, None, 0, S0)               # slice down fine
        assert i.shape == (2,)

    def test_mask_and_level_keys_never_alias(self):
        c = QueryCache(D, capacity=4)
        q = self._q()
        c.insert(q, np.zeros(1, np.float32), np.array([3]), None, 0, S0)
        assert c.lookup(q, 1, ("tenant", "t1"), 0, S0) is None
        assert c.lookup(q, 1, None, 1, S0) is None
        assert c.lookup(q, 1, None, 0, S0) is not None

    def test_stamp_change_flushes_everything(self):
        c = QueryCache(D, capacity=4)
        c.insert(self._q(0), np.zeros(1, np.float32), np.array([1]),
                 None, 0, S0)
        c.insert(self._q(1), np.zeros(1, np.float32), np.array([2]),
                 None, 0, S0)
        # any component moving (generation / mask_epoch / rebuilds) flushes
        assert c.lookup(self._q(0), 1, None, 0, (2, 1, 0)) is None
        assert c.lookup(self._q(1), 1, None, 0, (2, 1, 0)) is None
        assert c.invalidations == 1
        assert c.summary()["size"] == 0

    def test_lru_eviction_and_slot_reuse(self):
        c = QueryCache(D, capacity=2)
        q0, q1, q2 = self._q(0), self._q(1), self._q(2)
        one = np.zeros(1, np.float32)
        c.insert(q0, one, np.array([0]), None, 0, S0)
        c.insert(q1, one, np.array([1]), None, 0, S0)
        c.lookup(q0, 1, None, 0, S0)            # refresh q0
        c.insert(q2, one, np.array([2]), None, 0, S0)   # evicts q1 (LRU)
        assert c.lookup(q1, 1, None, 0, S0) is None
        assert c.lookup(q0, 1, None, 0, S0) is not None
        assert c.lookup(q2, 1, None, 0, S0) is not None
        assert c.summary()["size"] == 2
        # updating an existing key reuses its slot, no capacity leak
        c.insert(q2, one, np.array([9]), None, 0, S0)
        assert c.summary()["size"] == 2
        _, i, _ = c.lookup(q2, 1, None, 0, S0)
        assert i[0] == 9

    def test_near_duplicate_hit_within_eps(self):
        c = QueryCache(D, capacity=4, near_eps=1e-2)
        q = self._q()
        c.insert(q, np.zeros(1, np.float32), np.array([5]), None, 0, S0)
        near = q.copy()
        near[0] += 1e-3                          # d^2 = 1e-6 < 1e-2
        s, i, kind = c.lookup(near, 1, None, 0, S0)
        assert kind == "near" and i[0] == 5
        far = q + 1.0
        assert c.lookup(far, 1, None, 0, S0) is None
        assert c.hits_near == 1

    def test_near_scan_respects_mask_and_level(self):
        c = QueryCache(D, capacity=4, near_eps=1e-2)
        q = self._q()
        c.insert(q, np.zeros(1, np.float32), np.array([5]),
                 ("tenant", "a"), 1, S0)
        near = q.copy()
        near[0] += 1e-3
        assert c.lookup(near, 1, None, 1, S0) is None
        assert c.lookup(near, 1, ("tenant", "a"), 0, S0) is None
        assert c.lookup(near, 1, ("tenant", "a"), 1, S0) is not None

    def test_capacity_validated(self):
        with pytest.raises(ValueError):
            QueryCache(D, capacity=0)


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

class TestConfig:
    def test_validation(self):
        with pytest.raises(ValueError):
            AdaptiveConfig(levels=0)
        with pytest.raises(ValueError):
            AdaptiveConfig(recover_frac=0.0)
        with pytest.raises(ValueError):
            AdaptiveConfig(n_probe_scale=1.5)
        with pytest.raises(ValueError):
            CacheConfig(capacity=0)
        with pytest.raises(ValueError):
            CacheConfig(near_eps=-1.0)

    def test_from_dict_rejects_unknown_fields(self):
        with pytest.raises(ValueError):
            AdaptiveConfig.from_dict({"enabled": True, "bogus": 1})
        with pytest.raises(ValueError):
            CacheConfig.from_dict({"capcity": 8})

    def test_engine_config_round_trip(self):
        from repro.engine import EngineConfig
        cfg = EngineConfig(
            d_emb=D,
            adaptive=AdaptiveConfig(enabled=True, levels=3, depth_high=7),
            cache=CacheConfig(enabled=True, capacity=16, near_eps=0.5),
        )
        back = EngineConfig.from_dict(cfg.to_dict())
        assert back.adaptive == cfg.adaptive
        assert back.cache == cfg.cache

    def test_cli_flags(self):
        import argparse
        from repro.engine import EngineConfig
        ap = argparse.ArgumentParser()
        EngineConfig.add_flags(ap)
        args = ap.parse_args([
            "--adaptive", "--adaptive-levels", "3",
            "--adaptive-depth-high", "9", "--adaptive-wait-high-ms", "0",
            "--qcache", "--qcache-capacity", "64", "--qcache-near-eps",
            "0.25",
        ])
        cfg = EngineConfig.from_flags(args, d_emb=D)
        assert cfg.adaptive.enabled and cfg.adaptive.levels == 3
        assert cfg.adaptive.depth_high == 9
        assert cfg.adaptive.wait_high_ms is None     # 0 => depth-only
        assert cfg.cache.enabled and cfg.cache.capacity == 64
        assert cfg.cache.near_eps == 0.25


# ---------------------------------------------------------------------------
# Per-dispatch overrides through every backend variant
# ---------------------------------------------------------------------------

class TestOverridesDispatch:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_levels_dispatch_and_stamp(self, backend):
        eng, db = make_engine(backend, adaptive=ADAPTIVE)
        n = db.shape[0]
        qs = RNG.normal(size=(3, D)).astype(np.float32)
        for lvl in (0, 1, 2, 5):
            ov = eng.overrides_for_level(lvl)
            if lvl == 0:
                assert ov is None
            else:
                assert isinstance(ov, SearchOverrides)
                assert ov.level == min(lvl, ADAPTIVE.levels)
            reqs = [eng.check_request(SearchRequest(q)) for q in qs]
            for r in eng.execute_batch(reqs, overrides=ov):
                assert r.degraded_level == (0 if ov is None else ov.level)
                assert r.doc_ids.shape == (1,)
                assert 0 <= int(r.doc_ids[0]) < n
        gen, epoch, rebuilds = eng.cache_stamp()
        assert gen >= 1 and epoch >= 1 and rebuilds >= 0

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_level_zero_bit_for_bit_vs_static(self, backend):
        """Acceptance (c) in miniature: adaptive wiring enabled but idle
        must reproduce the static engine's top-k ids exactly."""
        static, _ = make_engine(backend)
        adaptive, _ = make_engine(backend, adaptive=ADAPTIVE)
        qs = RNG.normal(size=(8, D)).astype(np.float32)
        s_a, i_a = static.search(qs)
        s_b, i_b = adaptive.search(qs)
        np.testing.assert_array_equal(i_a, i_b)
        np.testing.assert_array_equal(s_a, s_b)

    def test_degraded_schedule_enters_lower_and_dims_superset(self):
        eng, _ = make_engine("flat", adaptive=ADAPTIVE)
        ov1, ov2 = eng.overrides_for_level(1), eng.overrides_for_level(2)
        assert ov1.sched is not None and ov1.sched.d_start < eng.sched.d_start
        assert ov2.sched.d_start <= ov1.sched.d_start
        assert ov1.sched.d_start >= ADAPTIVE.min_d_start
        # final width untouched; degraded stage dims precomputed everywhere
        assert ov1.sched.final_k == eng.sched.final_k
        assert set(eng.dims) >= {ov1.sched.d_start, ov2.sched.d_start}
        assert eng.backend.dims == eng.dims
        assert eng.store.dims == eng.dims

    def test_degraded_levels_not_slower_shapes(self):
        # n_probe / oversample fractions shrink monotonically with level
        eng, _ = make_engine("ivf", adaptive=ADAPTIVE)
        ov1, ov2 = eng.overrides_for_level(1), eng.overrides_for_level(2)
        assert 0 < ov2.n_probe_frac < ov1.n_probe_frac <= 1.0
        assert 0 < ov2.oversample_frac < ov1.oversample_frac <= 1.0


# ---------------------------------------------------------------------------
# Driver integration: cache in front of the queue, level-keyed entries
# ---------------------------------------------------------------------------

class TestDriverIntegration:
    def test_cache_hit_skips_queue_and_mutation_invalidates(self):
        eng, _ = make_engine("flat", cache=CacheConfig(enabled=True,
                                                       capacity=16))
        q = RNG.normal(size=D).astype(np.float32)
        with EngineDriver(eng, max_wait_ms=0.0) as drv:
            r1 = drv.retrieve(q, timeout=30)
            assert not r1.cached
            r2 = drv.retrieve(q, timeout=30)
            assert r2.cached
            np.testing.assert_array_equal(r1.doc_ids, r2.doc_ids)
            np.testing.assert_array_equal(r1.scores, r2.scores)
            assert r2.store_generation == r1.store_generation
            # cache hits never ride the queue counters
            assert drv.stats.n_submitted == 1
            eng.add_docs(RNG.normal(size=(2, D)).astype(np.float32))
            r3 = drv.retrieve(q, timeout=30)
            assert not r3.cached
            assert r3.store_generation > r1.store_generation
            s = drv.cache.summary()
            assert s["hits_exact"] == 1 and s["invalidations"] == 1

    def test_cache_entries_are_level_keyed(self):
        eng, _ = make_engine("ivf", adaptive=ADAPTIVE,
                             cache=CacheConfig(enabled=True, capacity=16))
        q = RNG.normal(size=D).astype(np.float32)
        with EngineDriver(eng, max_wait_ms=0.0) as drv:
            assert drv.adaptive is not None and drv.cache is not None
            r1 = drv.retrieve(q, timeout=30)
            assert not r1.cached and r1.degraded_level == 0
            # force a degraded level the way the control loop would
            drv.adaptive.level = 1
            r2 = drv.retrieve(q, timeout=30)
            assert not r2.cached           # level 1 is a different key
            assert r2.degraded_level == 1
            r3 = drv.retrieve(q, timeout=30)
            assert r3.cached and r3.degraded_level == 1
            drv.adaptive.level = 0
            r4 = drv.retrieve(q, timeout=30)
            assert r4.cached and r4.degraded_level == 0
            np.testing.assert_array_equal(r4.doc_ids, r1.doc_ids)

    def test_disabled_sections_leave_driver_untouched(self):
        eng, _ = make_engine("flat")
        with EngineDriver(eng, max_wait_ms=0.0) as drv:
            assert drv.adaptive is None and drv.cache is None
            r = drv.retrieve(RNG.normal(size=D).astype(np.float32),
                             timeout=30)
            assert not r.cached and r.degraded_level == 0


# The hypothesis property pinning "no cached result across a
# store/mask/rebuild bump" for all six backend variants lives in
# tests/test_adaptive_properties.py (module-level importorskip, same
# pattern as tests/test_properties.py).

"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
in interpret mode (CPU container; same kernel code targets TPU)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.distance_topk import l2_topk
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gather_rescore import gather_rescore
from repro.kernels.ivf_scan import ivf_scan_topk, pack_ivf_lists, update_pack
from repro.kernels import ref

RNG = np.random.default_rng(42)


def _random_ivf(n, n_lists, max_len, rng, *, coverage=1.0):
    """Random -1-padded member table over a subset of rows (no duplicates)."""
    lists = np.full((n_lists, max_len), -1, np.int32)
    rows = rng.permutation(n)[: int(n * coverage)]
    assign = rng.integers(0, n_lists, rows.size)
    for c in range(n_lists):
        mem = rows[assign == c][:max_len]
        lists[c, : mem.size] = mem
    return lists


def _id_sets(ids):
    return [set(int(x) for x in row if x >= 0) for row in np.asarray(ids)]


class TestDistanceTopK:
    @pytest.mark.parametrize("nq,n,d,k,bq,bn", [
        (16, 256, 32, 4, 8, 64),
        (100, 1000, 64, 8, 32, 128),     # uneven tiles
        (7, 130, 16, 3, 8, 64),          # heavy padding
        (32, 512, 128, 16, 32, 256),
    ])
    @pytest.mark.parametrize("merge", ["sort", "select"])
    def test_matches_ref(self, nq, n, d, k, bq, bn, merge):
        q = RNG.normal(size=(nq, d)).astype(np.float32)
        db = RNG.normal(size=(n, d)).astype(np.float32)
        s, i = l2_topk(jnp.asarray(q), jnp.asarray(db), k=k,
                       block_q=bq, block_n=bn, merge=merge, interpret=True)
        rs, ri = ref.l2_topk_ref(jnp.asarray(q), jnp.asarray(db), k)
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs),
                                   rtol=1e-4, atol=1e-4)
        assert (np.asarray(i) == np.asarray(ri)).mean() > 0.99

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q = jnp.asarray(RNG.normal(size=(16, 32)), dtype)
        db = jnp.asarray(RNG.normal(size=(128, 32)), dtype)
        s, i = l2_topk(q, db, k=4, block_q=8, block_n=64, interpret=True)
        rs, ri = ref.l2_topk_ref(q, db, 4)
        tol = 1e-4 if dtype == np.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs),
                                   rtol=tol, atol=tol)

    def test_merge_select_equals_sort(self):
        """The two in-kernel merge strategies are the same math; they must
        agree exactly — including tie-breaking (duplicated db rows give exact
        score ties) and on a db size that is not a multiple of block_n."""
        d, k = 16, 6
        base = RNG.normal(size=(40, d)).astype(np.float32)
        db = np.concatenate([base, base[:13]])   # 53 rows: dup-row ties +
        q = RNG.normal(size=(9, d)).astype(np.float32)  # pads both axes
        s_sort, i_sort = l2_topk(jnp.asarray(q), jnp.asarray(db), k=k,
                                 block_q=8, block_n=16, merge="sort",
                                 interpret=True)
        s_sel, i_sel = l2_topk(jnp.asarray(q), jnp.asarray(db), k=k,
                               block_q=8, block_n=16, merge="select",
                               interpret=True)
        np.testing.assert_allclose(np.asarray(s_sort), np.asarray(s_sel),
                                   rtol=0, atol=0)
        # both strategies break ties toward the lower db index
        np.testing.assert_array_equal(np.asarray(i_sort), np.asarray(i_sel))
        # and match the reference oracle
        rs, _ = ref.l2_topk_ref(jnp.asarray(q), jnp.asarray(db), k)
        np.testing.assert_allclose(np.asarray(s_sort), np.asarray(rs),
                                   rtol=1e-4, atol=1e-4)

    def test_precomputed_norms(self):
        q = jnp.asarray(RNG.normal(size=(8, 16)), jnp.float32)
        db = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
        sq = jnp.sum(db**2, axis=-1)
        s1, i1 = l2_topk(q, db, k=2, db_sq=sq, block_q=8, block_n=32,
                         interpret=True)
        s2, i2 = l2_topk(q, db, k=2, block_q=8, block_n=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


class TestIvfScan:
    """Fused IVF probe+scan kernel vs the jnp oracle and the XLA IVF path."""

    @pytest.mark.parametrize("n,d,n_lists,max_len,bm,nq,n_probe,k", [
        (300, 16, 8, 64, 16, 5, 3, 10),
        (250, 32, 6, 48, 16, 7, 4, 8),      # max_len not a block multiple
        (200, 8, 10, 13, 8, 4, 5, 6),       # heavy pad: 13 -> 16
        (120, 24, 4, 64, 64, 3, 2, 12),     # single chunk per list
    ])
    @pytest.mark.parametrize("merge", ["sort", "select"])
    def test_matches_ref(self, n, d, n_lists, max_len, bm, nq, n_probe, k,
                         merge):
        rng = np.random.default_rng(n + max_len)
        db = rng.normal(size=(n, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng, coverage=0.9)
        probe = np.stack([rng.choice(n_lists, n_probe, replace=False)
                          for _ in range(nq)]).astype(np.int32)
        q = rng.normal(size=(nq, d)).astype(np.float32)
        pack = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                              block_m=bm)
        s, i = ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                             jnp.asarray(lists), pack, k=k, merge=merge,
                             interpret=True)
        rs, ri = ref.ivf_scan_ref(jnp.asarray(q), jnp.asarray(db),
                                  jnp.asarray(lists), jnp.asarray(probe),
                                  dim=d, k=k)
        assert _id_sets(i) == _id_sets(ri)
        ss = np.sort(np.asarray(s), axis=1)
        rr = np.sort(np.asarray(rs), axis=1)
        fin = np.isfinite(rr)
        np.testing.assert_allclose(ss[fin], rr[fin], rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.isinf(ss), np.isinf(rr))

    def test_tombstoned_and_empty_lists(self):
        """Masked ids never surface; a fully-masked probe set yields -1."""
        rng = np.random.default_rng(7)
        n, d, n_lists, max_len = 150, 16, 6, 32
        db = rng.normal(size=(n, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng)
        lists[2] = -1                                 # empty list
        valid = rng.random(n) > 0.3
        masked = np.where((lists >= 0) & valid[np.maximum(lists, 0)],
                          lists, -1).astype(np.int32)
        pack = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                              block_m=16)
        q = rng.normal(size=(4, d)).astype(np.float32)
        probe = np.stack([[0, 2, 4], [1, 2, 5], [2, 3, 0], [2, 2 + 3, 1]]
                         ).astype(np.int32)
        s, i = ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                             jnp.asarray(masked), pack, k=8, interpret=True)
        ia = np.asarray(i)
        live = ia[ia >= 0]
        assert valid[live].all()                      # no tombstone returned
        # and against the oracle over the masked table
        rs, ri = ref.ivf_scan_ref(jnp.asarray(q), jnp.asarray(db),
                                  jnp.asarray(masked), jnp.asarray(probe),
                                  dim=d, k=8)
        assert _id_sets(i) == _id_sets(ri)

    def test_k_exceeds_candidates(self):
        rng = np.random.default_rng(3)
        n, d = 40, 8
        db = rng.normal(size=(n, d)).astype(np.float32)
        lists = _random_ivf(n, 4, 8, rng, coverage=0.5)
        pack = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                              block_m=8)
        q = rng.normal(size=(2, d)).astype(np.float32)
        probe = np.asarray([[0, 1], [2, 3]], np.int32)
        s, i = ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                             jnp.asarray(lists), pack, k=30, interpret=True)
        sa, ia = np.asarray(s), np.asarray(i)
        assert (ia >= 0).sum(1).max() <= 16           # at most 2 lists x 8
        assert np.isinf(sa[ia < 0]).all()

    @pytest.mark.parametrize("with_valid", [False, True])
    @pytest.mark.parametrize("with_tail", [False, True])
    def test_parity_vs_xla_sched_path(self, with_valid, with_tail):
        """The acceptance contract: identical top-k id sets to
        `ivf_progressive_search_sched` under fixed probes/schedule, across
        validity masking and tail extra_cand injection."""
        from repro.core import make_schedule
        from repro.core.ivf import (build_ivf, ivf_progressive_search_kernel,
                                    ivf_progressive_search_sched)
        rng = np.random.default_rng(17)
        n, d = 400, 64
        db = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(9, d)).astype(np.float32))
        sched = make_schedule(8, d, 32, final_k=5)
        ivf = build_ivf(db, 12)
        valid = (jnp.asarray(rng.random(n) > 0.15) if with_valid else None)
        tail = (jnp.asarray(np.r_[np.arange(n - 8, n),
                                  -np.ones(5)].astype(np.int32))
                if with_tail else None)
        kw = dict(n_probe=5, valid=valid, extra_cand=tail)
        s1, i1 = ivf_progressive_search_sched(
            q, db, ivf["centroids"], ivf["lists"], sched, **kw)
        s2, i2 = ivf_progressive_search_kernel(
            q, db, ivf["centroids"], ivf["lists"], sched, interpret=True,
            **kw)
        assert _id_sets(i1) == _id_sets(i2)
        np.testing.assert_allclose(
            np.sort(np.asarray(s1), axis=1), np.sort(np.asarray(s2), axis=1),
            rtol=1e-4, atol=1e-4)

    def test_int8_pack_composes(self):
        """int8 member slabs: valid results, near-f32 ranking quality."""
        rng = np.random.default_rng(23)
        n, d, n_lists, max_len = 400, 32, 8, 64
        db = rng.normal(size=(n, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng)
        q = rng.normal(size=(16, d)).astype(np.float32)
        probe = np.stack([rng.choice(n_lists, 4, replace=False)
                          for _ in range(16)]).astype(np.int32)
        pf = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                            block_m=16)
        p8 = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                            block_m=16, dtype="int8")
        assert p8["rows"].dtype == jnp.int8
        _, i_f = ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                               jnp.asarray(lists), pf, k=10, interpret=True)
        _, i_8 = ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                               jnp.asarray(lists), p8, k=10, interpret=True)
        overlap = np.mean([
            len(a & b) / max(len(a), 1)
            for a, b in zip(_id_sets(i_f), _id_sets(i_8))])
        assert overlap >= 0.8                   # int8 is stage-0 only; the
        # full-precision rescore ladder absorbs the residual ranking noise

    @pytest.mark.parametrize("dtype", ["float32", "int8"])
    def test_update_pack_absorbs_new_rows(self, dtype):
        """Incremental append: a row written into a spare slot scores like
        a built one (int8 codes reuse the stored scale)."""
        rng = np.random.default_rng(5)
        n, d, n_lists, max_len = 100, 16, 4, 32
        db = rng.normal(size=(n + 1, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng, coverage=0.5)
        pack = pack_ivf_lists(jnp.asarray(db[:n]), jnp.asarray(lists), dim=d,
                              block_m=16, dtype=dtype)
        # place the new row (id n) into list 1's first free slot
        slot = int((lists[1] >= 0).sum())
        lists[1, slot] = n
        pack = update_pack(pack, jnp.asarray(db), np.asarray([n], np.int32),
                           np.asarray([1 * pack["max_len"] + slot]))
        q = db[n:n + 1] + 0.01 * rng.normal(size=(1, d)).astype(np.float32)
        probe = np.asarray([[1, 0]], np.int32)
        _, i = ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                             jnp.asarray(lists), pack, k=1, interpret=True)
        assert int(np.asarray(i)[0, 0]) == n

    def test_bytes_model_fused_strictly_fewer(self):
        from repro.kernels.ivf_scan import stage0_bytes_model
        for d0 in (1, 4, 8, 64, 256):
            for mb in (4, 1):
                m = stage0_bytes_model(n_lists=64, max_len=128, n_probe=8,
                                       d0=d0, k=32, member_bytes=mb)
                assert m["fused_bytes"] < m["xla_bytes"]


class TestPqScan:
    """Fused PQ ADC LUT-scan kernel (flat + IVF-slab variants) vs the jnp
    ADC oracles and the XLA `pq_progressive_search` path."""

    @staticmethod
    def _codec(db, d, m, rng, n_codes=64):
        from repro.core.pq import pq_lut, train_pq
        cb = train_pq(jnp.asarray(db[:, :d]), m=m, n_codes=n_codes, n_iter=6)

        def lut_of(q):
            return pq_lut(jnp.asarray(q[:, :d]), cb)

        return cb, lut_of

    @pytest.mark.parametrize("n,d,m,bm,nq,k", [
        (300, 16, 4, 32, 5, 10),
        (250, 32, 8, 64, 7, 8),        # n not a block multiple
        (130, 8, 2, 128, 3, 6),        # single chunk, heavy pad
        (200, 24, 3, 16, 4, 12),       # odd subspace count
    ])
    @pytest.mark.parametrize("merge", ["sort", "select"])
    def test_flat_matches_ref(self, n, d, m, bm, nq, k, merge):
        from repro.core.pq import pq_encode
        from repro.kernels.pq_scan import pq_scan_topk
        rng = np.random.default_rng(n + m)
        db = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(nq, d)).astype(np.float32)
        cb, lut_of = self._codec(db, d, m, rng)
        codes = pq_encode(jnp.asarray(db[:, :d]), cb)
        ids = np.arange(n, dtype=np.int32)
        ids[rng.random(n) < 0.2] = -1              # tombstones
        lut = lut_of(q)
        s, i = pq_scan_topk(lut, codes, jnp.asarray(ids), k=k, block_m=bm,
                            merge=merge, interpret=True)
        rs, ri = ref.pq_scan_ref(lut, codes, jnp.asarray(ids), k=k)
        assert _id_sets(i) == _id_sets(ri)
        ss, rr = np.sort(np.asarray(s), 1), np.sort(np.asarray(rs), 1)
        fin = np.isfinite(rr)
        np.testing.assert_allclose(ss[fin], rr[fin], rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.isinf(ss), np.isinf(rr))
        # no tombstone ever surfaces
        live = np.asarray(i)[np.asarray(i) >= 0]
        assert (ids[live] >= 0).all()

    @pytest.mark.parametrize("merge", ["sort", "select"])
    def test_ivf_slab_matches_ref(self, merge):
        from repro.core.pq import pq_encode
        from repro.kernels.ivf_scan import pack_ivf_lists
        from repro.kernels.pq_scan import pq_ivf_scan_topk
        rng = np.random.default_rng(31)
        n, d, m, n_lists, max_len = 400, 32, 4, 8, 48   # 48 -> pads to 64
        db = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(9, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng, coverage=0.9)
        cb, lut_of = self._codec(db, d, m, rng)
        pack = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                              dtype="pq", pq_codebooks=cb, block_m=16)
        assert pack["rows"].dtype == jnp.uint8
        assert pack["sq"] is None                 # ADC needs no norm table
        probe = np.stack([rng.choice(n_lists, 4, replace=False)
                          for _ in range(9)]).astype(np.int32)
        s, i = pq_ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                                jnp.asarray(lists), pack, k=10, merge=merge,
                                interpret=True)
        codes_full = pq_encode(jnp.asarray(db[:, :d]), cb)
        rs, ri = ref.pq_ivf_scan_ref(lut_of(q), codes_full,
                                     jnp.asarray(lists), jnp.asarray(probe),
                                     k=10)
        assert _id_sets(i) == _id_sets(ri)
        ss, rr = np.sort(np.asarray(s), 1), np.sort(np.asarray(rs), 1)
        fin = np.isfinite(rr)
        np.testing.assert_allclose(ss[fin], rr[fin], rtol=1e-4, atol=1e-4)

    def test_ivf_slab_tombstones_and_empty_lists(self):
        """Masked ids never surface; a fully-masked probe set yields -1."""
        from repro.core.pq import pq_encode
        from repro.kernels.ivf_scan import pack_ivf_lists
        from repro.kernels.pq_scan import pq_ivf_scan_topk
        rng = np.random.default_rng(13)
        n, d, m, n_lists, max_len = 150, 16, 4, 6, 32
        db = rng.normal(size=(n, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng)
        lists[2] = -1                                 # empty list
        valid = rng.random(n) > 0.3
        masked = np.where((lists >= 0) & valid[np.maximum(lists, 0)],
                          lists, -1).astype(np.int32)
        cb, lut_of = self._codec(db, d, m, rng)
        pack = pack_ivf_lists(jnp.asarray(db), jnp.asarray(lists), dim=d,
                              dtype="pq", pq_codebooks=cb, block_m=16)
        q = rng.normal(size=(4, d)).astype(np.float32)
        probe = np.asarray([[0, 2, 4], [1, 2, 5], [2, 3, 0], [2, 5, 1]],
                           np.int32)
        s, i = pq_ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                                jnp.asarray(masked), pack, k=8,
                                interpret=True)
        ia = np.asarray(i)
        live = ia[ia >= 0]
        assert valid[live].all()                      # no tombstone returned
        codes_full = pq_encode(jnp.asarray(db[:, :d]), cb)
        rs, ri = ref.pq_ivf_scan_ref(lut_of(q), codes_full,
                                     jnp.asarray(masked), jnp.asarray(probe),
                                     k=8)
        assert _id_sets(i) == _id_sets(ri)

    @pytest.mark.parametrize("with_valid", [False, True])
    @pytest.mark.parametrize("with_tail", [False, True])
    def test_parity_vs_xla_adc_path(self, with_valid, with_tail):
        """The acceptance contract: the fused flat kernel path produces
        identical top-k id sets to the XLA ADC reference, across validity
        masking and tail extra_cand injection."""
        from repro.core import make_schedule
        from repro.core.pq import (build_pq_index, pq_progressive_search,
                                   pq_progressive_search_kernel)
        rng = np.random.default_rng(19)
        n, d = 400, 64
        db = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(9, d)).astype(np.float32))
        sched = make_schedule(16, d, 32, final_k=5)
        idx = build_pq_index(db, sched, m=4)
        valid = (jnp.asarray(rng.random(n) > 0.15) if with_valid else None)
        tail = (jnp.asarray(np.r_[np.arange(n - 8, n),
                                  -np.ones(5)].astype(np.int32))
                if with_tail else None)
        kw = dict(valid=valid, extra_cand=tail, oversample=2)
        s1, i1 = pq_progressive_search(q, idx, sched, **kw)
        s2, i2 = pq_progressive_search_kernel(q, idx, sched, interpret=True,
                                              block_m=64, **kw)
        assert _id_sets(i1) == _id_sets(i2)
        np.testing.assert_allclose(
            np.sort(np.asarray(s1), axis=1), np.sort(np.asarray(s2), axis=1),
            rtol=1e-4, atol=1e-4)

    def test_ivf_pq_search_end_to_end(self):
        """`ivf_progressive_search_kernel` over a pq pack: against the
        exact-over-probed-members baseline, ADC stage 0 with the default
        oversample loses nothing vs the f32 stage 0 — the full-precision
        rescore ladder absorbs the quantization noise."""
        import jax
        from repro.core import make_schedule
        from repro.core import truncated as T
        from repro.core.ivf import (build_ivf, ivf_progressive_search_kernel,
                                    ivf_progressive_search_sched)
        from repro.kernels.ivf_scan import pack_ivf_lists
        rng = np.random.default_rng(23)
        n, d = 400, 64
        db = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(16, d)).astype(np.float32))
        sched = make_schedule(16, d, 32, final_k=10)
        ivf = build_ivf(db, 12)
        # backend-default codec quality: 256 codes/subspace, 4x oversample
        cb, _ = self._codec(np.asarray(db), 16, 4, rng, n_codes=256)
        pack = pack_ivf_lists(db, ivf["lists"], dim=16, dtype="pq",
                              pq_codebooks=cb, block_m=16)
        _, i_pq = ivf_progressive_search_kernel(
            q, db, ivf["centroids"], ivf["lists"], sched, n_probe=6,
            pack=pack, pq_oversample=4, interpret=True)
        _, i_f = ivf_progressive_search_sched(
            q, db, ivf["centroids"], ivf["lists"], sched, n_probe=6)
        # exact top-10 over the same probed members at the full dim
        cs = T.l2_scores(q, ivf["centroids"])
        _, probe = jax.lax.top_k(-cs, 6)
        _, i_exact = ref.ivf_scan_ref(q, db, ivf["lists"], probe, dim=d,
                                      k=10)
        def recall(i):
            return np.mean([
                len(a & b) / max(len(b), 1)
                for a, b in zip(_id_sets(i), _id_sets(i_exact))])
        # both paths pay the same truncated-stage-0 noise; PQ must not pay
        # meaningfully more on top of it
        assert recall(i_pq) >= recall(i_f) - 0.05

    def test_oversampled_pool_seats_tail_rows(self):
        """Tail (un-absorbed appended) rows must be able to claim any slot
        of the oversampled stage-0 pool, not just the first s0.k: a tail
        row with a mediocre stage-0 prefix but a perfect full-dim match
        must beat stage-0-flattering decoys at the rescore."""
        from repro.core import make_schedule
        from repro.core.ivf import build_ivf, ivf_progressive_search_kernel
        from repro.core.pq import train_pq
        from repro.kernels.ivf_scan import pack_ivf_lists
        rng = np.random.default_rng(41)
        d, n_coded = 16, 80
        q = rng.normal(size=(1, d)).astype(np.float32)
        coded = (rng.normal(size=(n_coded, d)) * 8 + 20).astype(np.float32)
        # 4 decoys: perfect stage-0 prefix, terrible suffix; 4 true
        # matches: slightly-off prefix, perfect suffix
        decoys = np.concatenate(
            [np.repeat(q[:, :8], 4, axis=0),
             np.full((4, 8), 30.0, np.float32)], axis=1)
        true = np.repeat(q, 4, axis=0) + np.concatenate(
            [np.full((4, 8), 0.5, np.float32), np.zeros((4, 8), np.float32)],
            axis=1).astype(np.float32)
        db = jnp.asarray(np.concatenate([coded, decoys, true]))
        tail_ids = np.arange(n_coded, n_coded + 8, dtype=np.int32)
        sched = make_schedule(8, d, 4, final_k=4)
        ivf = build_ivf(db[:n_coded], 4)
        cb = train_pq(db[:n_coded, :8], m=2, n_codes=32, n_iter=4)
        pack = pack_ivf_lists(db, ivf["lists"], dim=8, dtype="pq",
                              pq_codebooks=cb, block_m=16)
        _, ids = ivf_progressive_search_kernel(
            jnp.asarray(q), db, ivf["centroids"], ivf["lists"], sched,
            n_probe=2, pack=pack, pq_oversample=4,
            extra_cand=jnp.asarray(tail_ids), interpret=True)
        # the 4 true matches fill the final top-4; every decoy loses
        assert set(np.asarray(ids)[0].tolist()) == set(
            range(n_coded + 4, n_coded + 8))

    def test_update_pack_absorbs_new_rows_pq(self):
        """Incremental append: a row written into a spare slot is encoded
        against the pack's frozen codebooks and scores like a built one."""
        from repro.kernels.ivf_scan import pack_ivf_lists, update_pack
        from repro.kernels.pq_scan import pq_ivf_scan_topk
        rng = np.random.default_rng(5)
        n, d, m, n_lists, max_len = 100, 16, 4, 4, 32
        db = rng.normal(size=(n + 1, d)).astype(np.float32)
        lists = _random_ivf(n, n_lists, max_len, rng, coverage=0.5)
        cb, _ = self._codec(db[:n], d, m, rng)
        pack = pack_ivf_lists(jnp.asarray(db[:n]), jnp.asarray(lists), dim=d,
                              dtype="pq", pq_codebooks=cb, block_m=16)
        slot = int((lists[1] >= 0).sum())
        lists[1, slot] = n
        pack = update_pack(pack, jnp.asarray(db), np.asarray([n], np.int32),
                           np.asarray([1 * pack["max_len"] + slot]))
        q = db[n:n + 1] + 0.01 * rng.normal(size=(1, d)).astype(np.float32)
        probe = np.asarray([[1, 0]], np.int32)
        _, i = pq_ivf_scan_topk(jnp.asarray(q), jnp.asarray(probe),
                                jnp.asarray(lists), pack, k=1,
                                interpret=True)
        assert int(np.asarray(i)[0, 0]) == n

    def test_pack_rejects_wrong_scanner(self):
        from repro.core.pq import train_pq
        from repro.kernels.ivf_scan import ivf_scan_topk, pack_ivf_lists
        from repro.kernels.pq_scan import pq_ivf_scan_topk
        rng = np.random.default_rng(2)
        db = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
        lists = jnp.asarray(_random_ivf(64, 4, 16, rng))
        cb = train_pq(db, m=4, n_codes=16, n_iter=2)
        pq_pack = pack_ivf_lists(db, lists, dim=16, dtype="pq",
                                 pq_codebooks=cb)
        f_pack = pack_ivf_lists(db, lists, dim=16)
        q = jnp.zeros((1, 16), jnp.float32)
        probe = jnp.zeros((1, 2), jnp.int32)
        with pytest.raises(ValueError, match="pq_scan"):
            ivf_scan_topk(q, probe, lists, pq_pack, k=4, interpret=True)
        with pytest.raises(ValueError, match="dtype='pq'"):
            pq_ivf_scan_topk(q, probe, lists, f_pack, k=4, interpret=True)
        with pytest.raises(ValueError, match="pq_codebooks"):
            pack_ivf_lists(db, lists, dim=16, dtype="pq")

    def test_flat_bytes_model_pq_strictly_under_int8(self):
        from repro.kernels.pq_scan import flat_stage0_bytes_model
        for d0, m in ((8, 1), (16, 2), (64, 8), (256, 32)):
            i8 = flat_stage0_bytes_model(n=65536, k=256, row_bytes=d0)
            pq = flat_stage0_bytes_model(n=65536, k=256, row_bytes=m,
                                         lut_bytes=m * 256 * 4)
            for key in ("xla_bytes", "fused_bytes"):
                assert pq[key] < i8[key]
            assert pq["fused_bytes"] < pq["xla_bytes"] + 8 * 256

    def test_ivf_bytes_model_pq_strictly_under_int8(self):
        from repro.kernels.ivf_scan import stage0_bytes_model
        for d0, m in ((16, 2), (64, 8), (256, 32)):
            i8 = stage0_bytes_model(n_lists=64, max_len=128, n_probe=8,
                                    d0=d0, k=32, member_bytes=1)
            pq = stage0_bytes_model(n_lists=64, max_len=128, n_probe=8,
                                    d0=d0, k=32, row_bytes=m,
                                    lut_bytes=m * 256 * 4, norms=False)
            assert pq["fused_bytes"] < i8["fused_bytes"]
            assert pq["fused_bytes"] < pq["xla_bytes"]


class TestGatherRescore:
    @pytest.mark.parametrize("nq,n,d,c,bc", [
        (8, 200, 64, 16, 8),
        (12, 500, 128, 20, 16),          # c not divisible by bc
        (4, 100, 256, 7, 4),
    ])
    def test_matches_ref(self, nq, n, d, c, bc):
        q = RNG.normal(size=(nq, d)).astype(np.float32)
        db = RNG.normal(size=(n, d)).astype(np.float32)
        cand = RNG.choice(n, size=(nq, c)).astype(np.int32)
        cand[0, c // 2:] = -1
        s = gather_rescore(jnp.asarray(q), jnp.asarray(db),
                           jnp.asarray(cand), block_c=bc, interpret=True)
        r = ref.gather_rescore_ref(jnp.asarray(q), jnp.asarray(db),
                                   jnp.asarray(cand))
        sa, ra = np.asarray(s), np.asarray(r)
        fin = np.isfinite(ra)
        np.testing.assert_allclose(sa[fin], ra[fin], rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.isinf(sa), np.isinf(ra))


class TestEmbeddingBag:
    @pytest.mark.parametrize("v,d,b,l,bb", [
        (100, 32, 16, 4, 8),
        (500, 64, 10, 7, 4),             # b not divisible by bb
        (50, 128, 4, 1, 2),
    ])
    @pytest.mark.parametrize("mode", ["sum", "mean"])
    def test_matches_ref(self, v, d, b, l, bb, mode):
        table = RNG.normal(size=(v, d)).astype(np.float32)
        idx = RNG.choice(v, size=(b, l)).astype(np.int32)
        idx[-1, l // 2:] = -1
        out = embedding_bag(jnp.asarray(table), jnp.asarray(idx),
                            mode=mode, block_b=bb, interpret=True)
        r = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx),
                                  mode=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,dh,causal,window", [
        (2, 4, 4, 64, 64, 32, True, None),
        (2, 4, 2, 64, 64, 32, False, None),     # GQA
        (1, 2, 2, 50, 70, 32, True, None),      # uneven + decode-aligned
        (1, 2, 2, 96, 96, 64, True, 16),        # sliding window
        (1, 4, 1, 1, 128, 64, False, None),     # single-token decode (MQA)
        (1, 2, 2, 33, 65, 16, True, 8),         # padding both axes + window
    ])
    def test_matches_ref(self, b, hq, hkv, sq, skv, dh, causal, window):
        q = RNG.normal(size=(b, hq, sq, dh)).astype(np.float32)
        k = RNG.normal(size=(b, hkv, skv, dh)).astype(np.float32)
        v = RNG.normal(size=(b, hkv, skv, dh)).astype(np.float32)
        o = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, window=window,
                            block_q=32, block_k=32, interpret=True)
        r = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), causal=causal,
                                    window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = jnp.asarray(RNG.normal(size=(1, 2, 32, 32)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(1, 2, 32, 32)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(1, 2, 32, 32)), jnp.bfloat16)
        o = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                            interpret=True)
        r = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestSegmentSum:
    @pytest.mark.parametrize("e,n,d,bn,ec", [
        (1000, 256, 32, 128, 256),
        (500, 128, 64, 64, 128),
        (2000, 384, 16, 128, 64),       # many chunks per block
        (50, 128, 8, 128, 32),          # sparse: most blocks empty
    ])
    def test_matches_ref(self, e, n, d, bn, ec):
        from repro.kernels.ops import segment_sum_op
        data = RNG.normal(size=(e, d)).astype(np.float32)
        seg = RNG.integers(0, n, e).astype(np.int32)
        seg[: e // 20] = -1             # padded edges
        out = segment_sum_op(jnp.asarray(data), jnp.asarray(seg),
                             num_segments=n, block_n=bn, edge_chunk=ec)
        masked = jnp.where((jnp.asarray(seg) >= 0)[:, None],
                           jnp.asarray(data), 0)
        expect = ref.segment_sum_ref(masked, jnp.maximum(jnp.asarray(seg), 0), n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_skewed_degree_distribution(self):
        """Power-law receivers: one node takes most edges."""
        from repro.kernels.ops import segment_sum_op
        e, n, d = 800, 128, 16
        data = RNG.normal(size=(e, d)).astype(np.float32)
        seg = np.zeros(e, np.int32)
        seg[: e // 2] = 0               # half the edges hit node 0
        seg[e // 2:] = RNG.integers(0, n, e - e // 2)
        out = segment_sum_op(jnp.asarray(data), jnp.asarray(seg),
                             num_segments=n, block_n=64, edge_chunk=64)
        expect = ref.segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)


class TestChunkedAttentionParity:
    """The model's jnp chunked attention must match the Pallas kernel —
    they are the same math on different substrates."""

    def test_chunked_equals_flash(self):
        from repro.layers.attention import chunked_attention
        q = jnp.asarray(RNG.normal(size=(2, 4, 64, 32)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.float32)
        a = chunked_attention(q, k, v, causal=True, window=0,
                              block_q=16, block_k=16)
        b = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)

    def test_chunked_window_matches_ref(self):
        from repro.layers.attention import chunked_attention
        q = jnp.asarray(RNG.normal(size=(1, 2, 48, 16)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 2, 48, 16)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 2, 48, 16)), jnp.float32)
        a = chunked_attention(q, k, v, causal=True, window=jnp.asarray(8),
                              block_q=16, block_k=16)
        r = ref.flash_attention_ref(q, k, v, causal=True, window=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)
